//! The VSW engine — Algorithm 1 of the paper.
//!
//! ```text
//! init(src_vertex_array, dst_vertex_array)
//! while active_vertex_ratio > 0:
//!     parallel for shard in all_shards:                # thread pool
//!         if ratio > 1/1000 or bloom[shard].has(active):
//!             load_to_memory(shard)                    # cache first
//!             for v in shard.vertices:
//!                 dst[v] = update(v, src)              # backend
//!     active = vertices that changed
//!     swap(src, dst)
//! ```
//!
//! Everything the paper measures hangs off this loop: per-iteration wall
//! time, activation ratio, shard skips (Fig 5), I/O bytes (Table II), cache
//! hits (§II-D.2) and memory (Fig 11).
//!
//! ## The shard prefetch pipeline
//!
//! With [`EngineConfig::prefetch_depth`] > 0 (the default), `load_to_memory`
//! moves off the compute path: a small I/O pool Bloom-screens, reads and
//! decompresses the next shards while the compute pool updates the current
//! ones, exactly the I/O/compute overlap of the journal version
//! (arXiv:1810.04334).  A semaphore caps decoded-but-unconsumed shards at
//! `prefetch_depth`, so the semi-external memory envelope holds.  Results
//! are bit-identical to the synchronous path for any thread count and any
//! depth (shard updates are pure per-shard functions of `src`, and every
//! shard's interval is written exactly once) — `tests/prefetch_pipeline.rs`
//! locks that in.  [`IterStats::io_wait`] / [`IterStats::compute`] expose
//! how much acquisition time the pipeline hides.
//!
//! ## The adaptive I/O governor
//!
//! With [`EngineConfig::adaptive`] the window, the shard issue order and
//! the cache/prefetch memory split all come from one per-iteration feedback
//! loop ([`crate::engine::Governor`]): the window grows while workers stall
//! on acquisition and shrinks when compute-bound (clamped to
//! `[1, prefetch_max]` and to what a finite cache budget can lend), shards
//! are issued hottest-first (Bloom active-density + miss history), and
//! mode-1 cache residents never wait for a read-ahead slot.  Every decision
//! is a function of *completed* iterations only, so results remain
//! bit-identical to every fixed configuration — `tests/governor_adaptive.rs`
//! and the extended determinism regression prove it.
//!
//! ## Zero-allocation steady state
//!
//! Three mechanisms make a warm-cache iteration allocation-free along the
//! vertex/edge axes and keep every core busy:
//!
//! * **Compressed-domain gather** ([`EngineConfig::stream_gather`], on by
//!   default for `Backend::Native`): a compressed-cache hit is consumed
//!   through [`crate::cache::ShardCache::fetch_view`] instead of decoding a
//!   fresh CSR — delta-varint payloads stream straight from the slot's
//!   `Arc`-shared bytes into the gather fold, byte codecs decompress into a
//!   pooled buffer that is walked in place, and disk reads are walked
//!   serialized.  Per-vertex fold order is bit-identical to the decoded
//!   path because the decoded path runs the very same
//!   [`crate::engine::backend::process_rows`] loop.
//! * **Worker scratch arenas**: compute workers own reusable active-set
//!   buffers ([`crate::util::threadpool::ThreadPool::broadcast_with`]),
//!   results are written straight into the destination array
//!   ([`SharedSlice::slice_mut`]) instead of through per-shard vectors, and
//!   active vertices merge deterministically from per-worker runs keyed by
//!   (shard, chunk).
//! * **Intra-shard chunking** ([`EngineConfig::chunk_rows`]): a ready shard
//!   is split into row chunks claimed off a shared board by every compute
//!   worker, so the largest shard no longer serializes the iteration tail
//!   on a single core (NXgraph's sub-interval observation,
//!   arXiv:1510.06916).  Chunks are pure per-row functions of `src`, so
//!   results stay bit-identical for every chunk size.
//!
//! Bloom screening also hashes each active vertex exactly once per
//! iteration ([`crate::bloom::digest`]); the digest array is reused by
//! every shard's screening probe and the governor's density scoring.

use std::cell::Cell;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::apps::{AnyProgram, ProgramContext, VertexProgram, VertexValue};
use crate::bloom::{digest, BloomFilter, Digest};
use crate::cache::deltavarint::DvPlan;
use crate::cache::{deltavarint, Codec, ShardCache, ShardView};
use crate::engine::backend::{
    process_rows_cfg, Backend, CsrRows, DeltaRows, DvRows, EdgeSource, ViewRows,
};
use crate::engine::simd;
use crate::engine::governor::{Governor, GovernorConfig};
use crate::engine::shared::SharedSlice;
use crate::engine::stats::{AnyRunResult, IterStats, RunResult, RunStats};
use crate::graph::csr::Csr;
use crate::graph::{AnyValues, VertexId};
use crate::obs;
use crate::runtime::EpochManifest;
use crate::sharding::preprocess::load_bloom_file;
use crate::storage::delta::DeltaShard;
use crate::storage::prefetch::{ReadAhead, Semaphore};
use crate::storage::uring::DirectShardReader;
use crate::storage::property::Property;
use crate::storage::shardfile::{self, PayloadLayout};
use crate::storage::vertexinfo::VertexInfo;
use crate::storage::{io, DatasetDir};
use crate::util::threadpool::{default_threads, ThreadPool};

/// Engine configuration (defaults mirror the paper's settings).
#[derive(Clone)]
pub struct EngineConfig {
    pub threads: usize,
    /// Hard iteration cap; `0` = use the app's default.
    pub max_iters: usize,
    /// Enable Bloom-filter selective scheduling (§II-D.1).
    pub selective: bool,
    /// Activation-ratio threshold below which selective scheduling engages
    /// (the paper uses 0.001).
    pub selective_threshold: f64,
    /// Cache codec (paper modes 1-4 + extensions).
    pub cache_codec: Codec,
    /// Cache budget in bytes; `0` disables the cache entirely (GraphMP-NC).
    pub cache_budget: usize,
    /// |new - old| > tol ⇒ vertex is active. 0.0 = exact equality (paper).
    pub convergence_tol: f32,
    pub backend: Backend,
    /// Shards the I/O pipeline may hold decoded ahead of compute.
    /// `0` = synchronous loads on the compute path (the conference paper's
    /// behavior); `>= 1` = pipelined prefetch (the journal version's
    /// overlap).  Results are identical either way.  Under `adaptive` this
    /// is only the *starting* window.
    pub prefetch_depth: usize,
    /// Enable the adaptive I/O governor ([`crate::engine::Governor`]):
    /// per-iteration feedback sizes the read-ahead window between 1 and
    /// `prefetch_max`, shards are issued hottest-first, and a finite cache
    /// budget lends its unused bytes to the in-flight allowance.  Results
    /// stay bit-identical to any fixed configuration.
    pub adaptive: bool,
    /// Hard ceiling for the adaptive window (`--prefetch-max`).
    pub prefetch_max: usize,
    /// Consume compressed-cache hits in the compressed domain (stream the
    /// payload into the gather fold) instead of decoding a CSR per hit.
    /// `Backend::Native` only; the xla backend always decodes.  Results
    /// are bit-identical either way — this is the default; switching it
    /// off is the fig7 ablation's decode path.
    pub stream_gather: bool,
    /// Rows per intra-shard work chunk scheduled across the compute pool
    /// (`--chunk-rows`); shards wider than this span several cores.
    /// `0` = never split.  Any value produces identical results.
    pub chunk_rows: usize,
    /// Snapshot epoch to open on a mutated dataset (`--epoch`); `None` =
    /// the manifest's current epoch.  Ignored (treated as the base) on a
    /// dataset without an epoch manifest.
    pub epoch: Option<u64>,
    /// Read shard files through the direct-I/O submission ring
    /// ([`DirectShardReader`]: `O_DIRECT` + io_uring where the kernel
    /// supports it, an aligned thread-pool fallback everywhere else)
    /// instead of buffered `read()`.  Bytes, accounting and results are
    /// identical; what changes is that cold reads bypass the page cache
    /// and the governor's window maps to real device queue depth
    /// (`--direct-io`, default off or `GRAPHMP_DIRECT_IO=1`).
    pub direct_io: bool,
    /// Use the vectorized gather kernels ([`crate::engine::simd`]) for
    /// rows the edge source can hand out as contiguous runs.  Results are
    /// bit-identical to the scalar fold; `--no-simd` (or `GRAPHMP_SIMD=0`)
    /// pins the scalar path for A/B runs.
    pub simd: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            threads: default_threads(),
            max_iters: 0,
            selective: true,
            selective_threshold: 0.001,
            cache_codec: Codec::SnapLite,
            cache_budget: usize::MAX,
            convergence_tol: 0.0,
            backend: Backend::Native,
            prefetch_depth: 2,
            adaptive: false,
            prefetch_max: 8,
            stream_gather: true,
            chunk_rows: 8192,
            epoch: None,
            direct_io: std::env::var("GRAPHMP_DIRECT_IO").map(|v| v == "1").unwrap_or(false),
            simd: simd::enabled_default(),
        }
    }
}

/// What one scheduled shard carries onto the chunk board.
enum WorkPayload {
    /// Bloom screening proved the shard inactive — carry values forward.
    Skipped,
    /// Acquisition failed; the error was already recorded.
    Failed,
    /// Decoded CSR: a mode-1 hit/admission, or any acquisition on the
    /// non-streaming (decode) path.
    Decoded(Arc<Csr>),
    /// Serialized shard bytes walked in place: a fresh disk read, or a
    /// byte-codec hit the producer decompressed into a pooled buffer
    /// (`pooled` ⇒ the buffer returns to the [`BufPool`] at finalize).
    View {
        bytes: Arc<Vec<u8>>,
        layout: PayloadLayout,
        pooled: bool,
    },
    /// Delta-varint payload streamed in the compressed domain — nothing
    /// is ever materialized for these.
    Dv { bytes: Arc<Vec<u8>>, plan: DvPlan },
}

/// One shard scheduled on the chunk board.  `permit` records whether the
/// producer took an in-flight read-ahead permit for it (cache residents
/// that materialize no decoded bytes may bypass the gate under the
/// adaptive governor).
struct ShardWork {
    shard: usize,
    payload: WorkPayload,
    permit: bool,
    num_chunks: usize,
    /// Next chunk to hand out; claims are serialized under the board lock.
    next_chunk: AtomicUsize,
    /// Chunks fully processed; the worker completing the last one
    /// finalizes the shard.
    done_chunks: AtomicUsize,
    edges: u64,
    /// Flight-recorder span inputs: wall time spent acquiring (read +
    /// decode) and decoding this shard, stamped by the producer, and fold
    /// nanoseconds accumulated by the compute workers.
    acquire_ns: u64,
    decode_local_ns: u64,
    fold_ns: AtomicU64,
}

impl ShardWork {
    fn new(shard: usize, payload: WorkPayload, num_chunks: usize, edges: u64) -> Self {
        Self {
            shard,
            payload,
            permit: false,
            num_chunks: num_chunks.max(1),
            next_chunk: AtomicUsize::new(0),
            done_chunks: AtomicUsize::new(0),
            edges,
            acquire_ns: 0,
            decode_local_ns: 0,
            fold_ns: AtomicU64::new(0),
        }
    }
}

struct BoardState {
    queue: VecDeque<Arc<ShardWork>>,
    /// Shards not yet finalized (pushed or still to be pushed).
    remaining: usize,
}

/// The two-level scheduler of the compute phase: producers push ready
/// shards, compute workers claim *chunks* off the front.  Chunk-level
/// claiming is what lets every core help finish the hottest shard instead
/// of letting it serialize the iteration tail on one worker.
struct ChunkBoard {
    state: Mutex<BoardState>,
    cv: Condvar,
}

impl ChunkBoard {
    fn new(total_shards: usize) -> Self {
        Self {
            state: Mutex::new(BoardState {
                queue: VecDeque::with_capacity(total_shards),
                remaining: total_shards,
            }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, work: ShardWork) {
        let mut s = self.state.lock().unwrap();
        s.queue.push_back(Arc::new(work));
        drop(s);
        self.cv.notify_all();
    }

    /// Claim the next chunk, blocking while shards are still in flight.
    /// Returns `None` once every shard has been finalized.
    fn claim(&self) -> Option<(Arc<ShardWork>, usize)> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(front) = s.queue.front() {
                // claims are serialized by the board lock, and the front is
                // popped when its last chunk is handed out, so `c` is
                // always in range
                let c = front.next_chunk.fetch_add(1, Ordering::Relaxed);
                let work = front.clone();
                if c + 1 == work.num_chunks {
                    s.queue.pop_front();
                }
                return Some((work, c));
            }
            if s.remaining == 0 {
                return None;
            }
            s = self.cv.wait(s).unwrap();
        }
    }

    /// Mark one shard fully processed; wakes waiters so they can re-check
    /// the exit condition (or pick up newly pushed work).
    fn finalized(&self) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        drop(s);
        self.cv.notify_all();
    }
}

/// Freelist of payload-sized buffers for byte-codec compressed hits: the
/// producer decompresses into one, chunk workers read it shared, and the
/// finalizing worker returns it.  Bounded by the in-flight window, so the
/// steady state allocates nothing per shard.
struct BufPool(Mutex<Vec<Arc<Vec<u8>>>>);

impl BufPool {
    fn new() -> Self {
        Self(Mutex::new(Vec::new()))
    }

    fn take(&self) -> Vec<u8> {
        let mut g = self.0.lock().unwrap();
        while let Some(a) = g.pop() {
            // a straggling reference means the buffer is still in use
            // somewhere; drop that entry and keep looking
            if let Ok(v) = Arc::try_unwrap(a) {
                return v;
            }
        }
        Vec::new()
    }

    fn put(&self, a: Arc<Vec<u8>>) {
        self.0.lock().unwrap().push(a);
    }
}

/// Per-compute-worker reusable buffers, owned across iterations via
/// [`ThreadPool::broadcast_with`] — the scratch arena that removes the
/// per-shard-per-iteration allocations from the steady state.
#[derive(Default)]
struct WorkerScratch {
    /// Newly-active vertices found by this worker, appended chunk by chunk.
    active: Vec<VertexId>,
    /// `(shard, chunk, start, len)` runs into `active`; merged in
    /// deterministic (shard, chunk) order after the parallel phase.
    runs: Vec<(usize, usize, usize, usize)>,
}

/// One epoch's complete read view, resolved from the snapshot manifest:
/// which base shard / Bloom / delta files a reader at this epoch sees,
/// plus the metadata those files imply.  **Immutable once built** — the
/// engine swaps a fresh `Arc<EpochState>` in on refresh and every run
/// clones the Arc exactly once at its start, so an in-flight run (or a
/// server session holding the Arc) is structurally pinned to its epoch:
/// there is no window in which it can observe half of one epoch and half
/// of another.
pub struct EpochState {
    /// Snapshot epoch id (0 on a never-mutated dataset).
    pub epoch: u64,
    /// Dataset property with `info.num_edges` reflecting this epoch's
    /// *live* edge count.
    pub property: Property,
    /// Degree arrays as of this epoch.
    pub vertex_info: VertexInfo,
    pub blooms: Vec<BloomFilter>,
    /// Per-shard base file paths at this epoch (compaction renames them).
    pub shard_paths: Vec<PathBuf>,
    /// Epoch at which each base shard file was last rewritten — the key
    /// every cache probe/insert for that shard carries.
    pub shard_epochs: Vec<u64>,
    /// Per-shard resident delta state (`None` = shard has no mutations).
    pub deltas: Vec<Option<Arc<DeltaShard>>>,
}

fn load_epoch_state(dir: &DatasetDir, requested: Option<u64>) -> Result<EpochState> {
    let mut property = Property::load(&dir.property_path()).context("property")?;
    let manifest = EpochManifest::load_or_bootstrap(dir, &property)?;
    let id = requested.unwrap_or(manifest.current);
    let entry = manifest.epoch(id)?;
    let p = property.num_shards();
    anyhow::ensure!(entry.shards.len() == p, "epoch {id} shard table disagrees with property");
    let vertex_info = VertexInfo::load(&dir.root.join(&entry.vertexinfo))
        .with_context(|| format!("vertexinfo (epoch {id})"))?;
    anyhow::ensure!(
        vertex_info.num_vertices() as u64 == property.info.num_vertices,
        "vertexinfo/property disagree"
    );
    let mut blooms = Vec::with_capacity(p);
    let mut shard_paths = Vec::with_capacity(p);
    let mut shard_epochs = Vec::with_capacity(p);
    let mut deltas = Vec::with_capacity(p);
    for (i, s) in entry.shards.iter().enumerate() {
        blooms.push(
            load_bloom_file(&dir.root.join(&s.bloom)).with_context(|| format!("bloom {i}"))?,
        );
        shard_paths.push(dir.root.join(&s.shard));
        shard_epochs.push(s.shard_epoch);
        deltas.push(match &s.delta {
            Some(f) => {
                let d = DeltaShard::load(&dir.root.join(f))
                    .with_context(|| format!("delta shard {i}"))?;
                let (lo, hi) = property.interval(i);
                anyhow::ensure!((d.lo, d.hi) == (lo, hi), "delta shard {i} interval");
                Some(Arc::new(d))
            }
            None => None,
        });
    }
    // surface the epoch's live edge count through the stats/CLI paths
    property.info.num_edges = entry.num_edges;
    Ok(EpochState {
        epoch: id,
        property,
        vertex_info,
        blooms,
        shard_paths,
        shard_epochs,
        deltas,
    })
}

impl EpochState {
    fn max_shard_bytes(&self) -> u64 {
        self.property
            .intervals
            .windows(2)
            .map(|w| (w[1] - w[0]) as u64 * 16)
            .max()
            .unwrap_or(0)
    }
}

/// The engine's worker pools.  [`ThreadPool`] batches share a completion
/// counter, so one `Pools` instance must never run two batches at once —
/// the engine hands them out through a mutex and builds a fresh throwaway
/// set when a second run arrives concurrently (thread counts are identical
/// either way, so results don't depend on which set a run got).
struct Pools {
    compute: ThreadPool,
    /// Dedicated I/O workers for the prefetch pipeline (None ⇔ the
    /// synchronous path: depth 0 and the governor disabled).
    io: Option<ThreadPool>,
}

impl Pools {
    fn build(cfg: &EngineConfig) -> Self {
        let compute = ThreadPool::new(cfg.threads.max(1));
        let io = if cfg.prefetch_depth > 0 || cfg.adaptive {
            // a few readers saturate the pipeline; decode parallelism is
            // bounded by the in-flight window anyway
            let readers = if cfg.adaptive { cfg.prefetch_max } else { cfg.prefetch_depth };
            Some(ThreadPool::new(readers.clamp(1, 4)))
        } else {
            None
        };
        Self { compute, io }
    }
}

/// Warm-start state for an incremental re-run on a mutated dataset: the
/// previous epoch's fixpoint values plus the vertices whose in-edges the
/// mutations touched (see [`crate::graph::mutation::incremental_plan`]).
pub struct WarmStart<V> {
    pub values: Vec<V>,
    pub active: Vec<VertexId>,
}

/// Fold a chunk's rows, merging the shard's resident delta (if any) into
/// the stream.  Free function because the per-payload arms instantiate it
/// with different `EdgeSource` types.  `pub(crate)` because the
/// partitioned step ([`crate::engine::partition`]) folds its owned shards
/// through this exact function — sharing it is what makes the partitioned
/// per-shard results bit-identical to the single-process loop.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fold_chunk<V: VertexValue, P: VertexProgram<V> + ?Sized, S: EdgeSource>(
    app: &P,
    rows: S,
    delta: Option<&DeltaShard>,
    start_row: usize,
    src: &[V],
    out_deg: &[u32],
    ctx: &ProgramContext,
    simd: bool,
    out: &mut [V],
) -> Result<()> {
    match delta {
        Some(d) => process_rows_cfg(
            app,
            &mut DeltaRows::new(rows, d, start_row, out.len()),
            src,
            out_deg,
            ctx,
            simd,
            out,
        ),
        None => {
            let mut rows = rows;
            process_rows_cfg(app, &mut rows, src, out_deg, ctx, simd, out)
        }
    }
}

/// An opened dataset ready to run programs (GraphMP's steady state: all
/// vertices + metadata in memory, edges on disk/cache).
///
/// Shared-engine model (`graphmp serve`): every method that runs or
/// inspects takes `&self`, so one engine behind an `Arc` serves many
/// concurrent sessions.  The epoch view lives in a single
/// `RwLock<Arc<EpochState>>` that [`Self::refresh_latest`] replaces
/// *wholesale* — a reader either sees the old snapshot or the new one,
/// never a mix — and runs pin themselves by cloning the Arc once up
/// front ([`Self::snapshot`] / [`Self::run_pinned`]).
pub struct VswEngine {
    dir: DatasetDir,
    /// Current epoch snapshot; swapped atomically by `refresh_latest`.
    state: RwLock<Arc<EpochState>>,
    /// Shared across epochs — slots are keyed per call by the reader's
    /// `shard_epochs[shard]`, so stale payloads can't cross epochs.
    /// Behind an `Arc` so a [`Self::with_config`] view whose override
    /// keeps the cache shape can share the warmed slots.
    cache: Arc<ShardCache>,
    /// Worker pools, leased per run (see [`Pools`]).
    pools: Mutex<Pools>,
    /// Adaptive I/O governor; with `cfg.adaptive == false` it pins every
    /// decision at the fixed-knob behavior.
    governor: Governor,
    /// Direct-I/O submission ring; `Some` iff `cfg.direct_io`.  Shared by
    /// the load-time prefetcher and every run's cold-shard reads so the
    /// governor's window feedback lands on one queue-depth knob.
    direct: Option<Arc<DirectShardReader>>,
    cfg: EngineConfig,
    pub load_wall: std::time::Duration,
}

impl VswEngine {
    /// Open a preprocessed dataset: load property, vertex info and Bloom
    /// filters (the paper's "data loading" phase; shards stay on disk but
    /// are opportunistically pre-cached when a budget exists).  On a
    /// mutated dataset the epoch manifest picks which shard / bloom /
    /// delta files this reader sees ([`EngineConfig::epoch`]).
    pub fn open(dir: DatasetDir, cfg: EngineConfig) -> Result<Self> {
        let t0 = Instant::now();
        let st = load_epoch_state(&dir, cfg.epoch)?;
        let p = st.property.num_shards();
        // default admission is no-evict (optimal under the cyclic sweep);
        // the adaptive governor installs per-shard priorities every
        // iteration, which makes replacement smarter than the cyclic
        // degenerate case — so adaptive mode runs with eviction enabled
        // and the victim is always the coldest (lowest-priority) shard
        let mut cache = ShardCache::new(p, cfg.cache_codec, cfg.cache_budget.max(1));
        if cfg.adaptive {
            cache = cache.with_eviction();
        }
        let cache_enabled = cfg.cache_budget > 0;
        let direct = cfg
            .direct_io
            .then(|| DirectShardReader::new(cfg.prefetch_depth.max(1)));
        // warm the cache during loading, like the paper's loading phase
        // ("places processed shards in the cache if possible"); with
        // prefetching, disk reads run ahead of the (CPU-bound) compression
        // inserts, shortening the load phase Fig 6 measures
        if cache_enabled {
            for (i, bytes) in
                ReadAhead::with_reader(st.shard_paths.clone(), cfg.prefetch_depth, direct.clone())
                    .enumerate()
            {
                cache.insert(
                    i,
                    st.shard_epochs[i],
                    &bytes.with_context(|| format!("warming shard {i}"))?,
                )?;
            }
        }
        let pools = Pools::build(&cfg);
        let governor = Governor::new(
            GovernorConfig::from_engine(cfg.adaptive, cfg.prefetch_depth, cfg.prefetch_max),
            st.max_shard_bytes() as usize,
        );
        Ok(Self {
            dir,
            state: RwLock::new(Arc::new(st)),
            cache: Arc::new(cache),
            pools: Mutex::new(pools),
            governor,
            direct,
            cfg,
            load_wall: t0.elapsed(),
        })
    }

    /// A per-request view of this engine under different knobs (the
    /// `graphmp serve` `iters`/`threads`/`codec` overrides): shares the
    /// dataset handle and the *current* epoch snapshot, reuses the warmed
    /// shard cache when the override keeps its shape (same codec, budget
    /// and eviction mode) and builds a fresh cold one otherwise, and gets
    /// its own pools + governor so an overridden run never perturbs the
    /// resident configuration.  Results are knob-invariant (the
    /// conformance matrix locks that), so overridden runs stay
    /// bit-identical to the resident engine's.
    pub fn with_config(&self, cfg: EngineConfig) -> Result<VswEngine> {
        anyhow::ensure!(
            cfg.epoch == self.cfg.epoch,
            "config overrides cannot re-pin the epoch; open a fresh engine instead"
        );
        let st = self.snapshot();
        let same_cache = cfg.cache_codec == self.cfg.cache_codec
            && cfg.cache_budget == self.cfg.cache_budget
            && cfg.adaptive == self.cfg.adaptive;
        let cache = if same_cache {
            self.cache.clone()
        } else {
            let mut c = ShardCache::new(
                st.property.num_shards(),
                cfg.cache_codec,
                cfg.cache_budget.max(1),
            );
            if cfg.adaptive {
                c = c.with_eviction();
            }
            Arc::new(c)
        };
        let direct = if cfg.direct_io == self.cfg.direct_io {
            self.direct.clone()
        } else {
            cfg.direct_io.then(|| DirectShardReader::new(cfg.prefetch_depth.max(1)))
        };
        let pools = Pools::build(&cfg);
        let governor = Governor::new(
            GovernorConfig::from_engine(cfg.adaptive, cfg.prefetch_depth, cfg.prefetch_max),
            st.max_shard_bytes() as usize,
        );
        Ok(Self {
            dir: self.dir.clone(),
            state: RwLock::new(st),
            cache,
            pools: Mutex::new(pools),
            governor,
            direct,
            cfg,
            load_wall: self.load_wall,
        })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The direct-I/O reader, when `cfg.direct_io` is on.  Exposed so
    /// callers (benches, tests) can inspect its direct/fallback counters.
    pub fn direct_reader(&self) -> Option<&Arc<DirectShardReader>> {
        self.direct.as_ref()
    }

    /// The engine's *current* epoch snapshot.  A clone of the returned Arc
    /// stays valid — and keeps serving bit-identical results — no matter
    /// how many refreshes happen afterwards; pass it to
    /// [`Self::run_pinned`] to keep a whole session on one epoch.
    pub fn snapshot(&self) -> Arc<EpochState> {
        self.state.read().unwrap().clone()
    }

    /// The snapshot epoch this engine currently reads.
    pub fn epoch(&self) -> u64 {
        self.snapshot().epoch
    }

    /// Direct-I/O traffic split, `(direct, fallback)` reads, when this
    /// engine runs a submission ring (`--direct-io`).  Surfaced by
    /// `graphmp info` and the daemon's `stats` verb.
    pub fn direct_counts(&self) -> Option<(u64, u64)> {
        self.direct.as_ref().map(|r| r.counts())
    }

    /// The dataset property as of the current epoch (live edge count
    /// included).
    pub fn property(&self) -> Property {
        self.snapshot().property.clone()
    }

    /// Re-resolve the dataset's *latest* epoch on a live engine: build a
    /// complete new [`EpochState`] (delta shards, Bloom filters, degree
    /// arrays, shard file epochs) and swap it in atomically.  In-flight
    /// runs hold the previous Arc and finish on their epoch untouched; the
    /// cache needs no re-keying because every probe carries its caller's
    /// shard epoch — slots whose base file a compaction rewrote invalidate
    /// lazily on the next current-epoch probe, while slots of untouched
    /// shards (and every ingest-only epoch, which never rewrites base
    /// bytes) stay warm.  Returns the epoch now being read.  Refuses on an
    /// engine pinned to an explicit historical epoch.
    pub fn refresh_latest(&self) -> Result<u64> {
        anyhow::ensure!(
            self.cfg.epoch.is_none(),
            "engine is pinned to epoch {:?}; open a fresh engine instead",
            self.cfg.epoch
        );
        let next = load_epoch_state(&self.dir, None)?;
        let id = next.epoch;
        let mut cur = self.state.write().unwrap();
        // epoch ids are monotonic; never swap backwards if a concurrent
        // refresh already installed something newer
        if id > cur.epoch {
            *cur = Arc::new(next);
        }
        Ok(cur.epoch)
    }

    pub fn cache(&self) -> &ShardCache {
        &self.cache
    }

    /// The run's adaptive I/O governor (frozen at the fixed-knob behavior
    /// unless [`EngineConfig::adaptive`] is set).
    pub fn governor(&self) -> &Governor {
        &self.governor
    }

    /// Estimated resident memory (Fig 11's metric): vertex arrays, degree
    /// arrays, Bloom filters, cache contents, plus per-thread shard
    /// buffers and the prefetch pipeline's in-flight slots.  The pipeline
    /// term uses the governor's window *high-water mark*, not the
    /// configured depth — under `--adaptive` the window moves, and the
    /// honest memory figure is the largest it ever got.
    ///
    /// Compressed-domain accounting: with [`EngineConfig::stream_gather`]
    /// a compressed hit no longer materializes a decoded CSR — an
    /// in-flight slot holds at most a payload-sized pooled buffer (byte
    /// codecs) or nothing beyond the cache's own bytes (delta-varint,
    /// which streams from the slot; such residents may also bypass the
    /// window gate precisely because they add no decoded bytes).  The
    /// `(threads + window-high-water) × max-shard-bytes` term kept here is
    /// therefore a *ceiling* on the in-flight footprint: Fig 11 can only
    /// over-report, never under-report, which keeps the figure honest.
    pub fn memory_estimate(&self) -> u64 {
        self.memory_estimate_for(&self.snapshot())
    }

    fn memory_estimate_for(&self, st: &EpochState) -> u64 {
        let v = st.property.info.num_vertices;
        let vertex_arrays = 2 * 4 * v; // src + dst f32
        let degree_arrays = 2 * 4 * v; // in + out u32
        let blooms: u64 = st.blooms.iter().map(|b| b.size_bytes() as u64).sum();
        let cache = self.cache.used_bytes() as u64;
        let shard_buffers =
            (self.cfg.threads + self.governor.high_water()) as u64 * st.max_shard_bytes();
        // resident delta shards (the mutation subsystem keeps them decoded)
        let deltas: u64 =
            st.deltas.iter().flatten().map(|d| d.resident_bytes() as u64).sum();
        // The observability layer (metrics series + trace ring) is resident
        // alongside the run, so Fig-11-style accounting charges it too.
        vertex_arrays + degree_arrays + blooms + cache + shard_buffers + deltas
            + obs::overhead_bytes()
    }

    /// Label value for this engine's metric series: the dataset directory
    /// name (`tiny.gmp`), stable across epochs and sessions.
    fn dataset_label(&self) -> String {
        self.dir.root.file_name().and_then(|s| s.to_str()).unwrap_or("dataset").to_string()
    }

    /// Push one completed iteration's signals into the metrics registry:
    /// cache totals are mirrored (`counter_to`), per-iteration clocks are
    /// added, governor/window state is gauged.  A handful of relaxed
    /// atomics per *iteration* — invisible next to a shard fold.
    fn obs_iteration(&self, st: &EpochState, it: &IterStats, lent_bytes: usize) {
        use crate::obs::metrics as m;
        let ds = self.dataset_label();
        let l: &[(&str, &str)] = &[("dataset", ds.as_str())];
        let cs = &self.cache.stats;
        m::counter_to("graphmp_cache_hits_total", l, cs.hits.load(Ordering::Relaxed));
        m::counter_to("graphmp_cache_misses_total", l, cs.misses.load(Ordering::Relaxed));
        m::counter_to("graphmp_cache_evictions_total", l, cs.evictions.load(Ordering::Relaxed));
        m::counter_to(
            "graphmp_cache_invalidations_total",
            l,
            cs.invalidated.load(Ordering::Relaxed),
        );
        m::gauge_set("graphmp_cache_resident_bytes", l, self.cache.used_bytes() as u64);
        m::counter_add("graphmp_engine_iterations_total", l, 1);
        m::counter_add("graphmp_engine_io_wait_seconds_total", l, it.io_wait.as_nanos() as u64);
        m::counter_add("graphmp_engine_compute_seconds_total", l, it.compute.as_nanos() as u64);
        m::counter_add("graphmp_engine_decode_seconds_total", l, it.decode_ns);
        m::gauge_set_f64("graphmp_engine_active_ratio", l, it.active_ratio);
        m::gauge_set("graphmp_engine_window", l, it.prefetch_depth as u64);
        m::gauge_set("graphmp_engine_lent_bytes", l, lent_bytes as u64);
        m::gauge_set("graphmp_engine_epoch", l, st.epoch);
        m::observe_secs("graphmp_iter_seconds", l, it.wall.as_secs_f64());
        if let Some(r) = &self.direct {
            let (direct, fallback) = r.counts();
            m::counter_to("graphmp_uring_direct_reads_total", l, direct);
            m::counter_to("graphmp_uring_fallback_reads_total", l, fallback);
            m::gauge_set("graphmp_uring_queue_depth", l, r.queue_depth() as u64);
        }
    }

    /// Run a lane-erased program (the CLI path): dispatches to the typed
    /// [`Self::run`] for the program's value lane.
    pub fn run_any(&self, app: &AnyProgram) -> Result<AnyRunResult> {
        self.run_any_pinned(&self.snapshot(), app)
    }

    /// [`Self::run_any`] against an explicit epoch snapshot: the server's
    /// session path, where a session captured its snapshot at `open` time
    /// and must keep reading it even after `refresh_latest` moved the
    /// engine forward.
    pub fn run_any_pinned(&self, st: &Arc<EpochState>, app: &AnyProgram) -> Result<AnyRunResult> {
        Ok(match app {
            AnyProgram::F32(p) => {
                let r = self.run_pinned(st, p.as_ref())?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }
            AnyProgram::F64(p) => {
                let r = self.run_pinned(st, p.as_ref())?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }
            AnyProgram::U32(p) => {
                let r = self.run_pinned(st, p.as_ref())?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }
            AnyProgram::U64(p) => {
                let r = self.run_pinned(st, p.as_ref())?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }
        })
    }

    /// Lane-erased warm start (the CLI's `--incremental` path): `values`
    /// must be on the program's lane (a saved fixpoint from a prior
    /// epoch), `active` the restart seed.  The caller is responsible for
    /// eligibility — monotone program, insert-only history — see
    /// [`crate::graph::mutation::incremental_plan`]; delete-bearing plans
    /// go through [`Self::run_any_plan`] instead.
    pub fn run_any_warm(
        &self,
        app: &AnyProgram,
        values: AnyValues,
        active: Vec<VertexId>,
    ) -> Result<AnyRunResult> {
        Ok(match (app, values) {
            (AnyProgram::F32(p), AnyValues::F32(values)) => {
                let r = self.run_seeded(p.as_ref(), Some(WarmStart { values, active }))?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }
            (AnyProgram::F64(p), AnyValues::F64(values)) => {
                let r = self.run_seeded(p.as_ref(), Some(WarmStart { values, active }))?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }
            (AnyProgram::U32(p), AnyValues::U32(values)) => {
                let r = self.run_seeded(p.as_ref(), Some(WarmStart { values, active }))?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }
            (AnyProgram::U64(p), AnyValues::U64(values)) => {
                let r = self.run_seeded(p.as_ref(), Some(WarmStart { values, active }))?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }
            (app, values) => anyhow::bail!(
                "saved values are on the {} lane but app {} runs on {}",
                values.lane().name(),
                app.name(),
                app.lane().name()
            ),
        })
    }

    /// Delete-capable warm restart ([`crate::graph::mutation::SeedPlan`]):
    /// reset every vertex in `plan.reset` back to `init` (a delete may have
    /// orphaned its saved value), then warm-run with `plan.seed` active.
    /// With an empty reset set this is exactly [`Self::run_any_warm`].
    pub fn run_any_plan(
        &self,
        app: &AnyProgram,
        values: AnyValues,
        plan: &crate::graph::mutation::SeedPlan,
    ) -> Result<AnyRunResult> {
        let st = self.snapshot();
        let n = st.property.info.num_vertices;
        anyhow::ensure!(
            plan.reset.iter().all(|&v| (v as u64) < n),
            "reset set references vertices outside the dataset"
        );
        let ctx = ProgramContext { num_vertices: n };
        let active = plan.seed.clone();
        macro_rules! lane {
            ($p:expr, $values:expr) => {{
                let mut values = $values;
                for &v in &plan.reset {
                    values[v as usize] = $p.init(v, &ctx);
                }
                let r = self.run_seeded_at(&st, $p.as_ref(), Some(WarmStart { values, active }))?;
                AnyRunResult { values: r.values.into(), stats: r.stats }
            }};
        }
        Ok(match (app, values) {
            (AnyProgram::F32(p), AnyValues::F32(values)) => lane!(p, values),
            (AnyProgram::F64(p), AnyValues::F64(values)) => lane!(p, values),
            (AnyProgram::U32(p), AnyValues::U32(values)) => lane!(p, values),
            (AnyProgram::U64(p), AnyValues::U64(values)) => lane!(p, values),
            (app, values) => anyhow::bail!(
                "saved values are on the {} lane but app {} runs on {}",
                values.lane().name(),
                app.name(),
                app.lane().name()
            ),
        })
    }

    /// Incremental Sum-lane maintenance: recompute only `rows` of a
    /// *single-pass* Sum program (effective `max_iters == 1`, e.g. SpMV)
    /// and splice the results into `baseline`, the previous epoch's
    /// fixpoint.  Each row of a single-pass program is independent —
    /// `apply(fold over its in-edges of the init vector, init)` — and a
    /// mutation only changes the in-edge list of its destination row, so
    /// recomputing exactly those rows through the same
    /// [`fold_chunk`] the full engine uses (same merged base+delta stream,
    /// same fixed fold order, same SIMD kernels) is bit-identical to a
    /// cold recompute.  Eligibility — Sum reduce, single pass, a gather
    /// that never reads `src_out_deg` — is the caller's job
    /// (`engine::standing::advance`).
    pub fn run_any_rows(
        &self,
        app: &AnyProgram,
        baseline: AnyValues,
        rows: &[VertexId],
    ) -> Result<AnyRunResult> {
        let st = self.snapshot();
        macro_rules! lane {
            ($p:expr, $values:expr) => {{
                let mut values = $values;
                let stats = self.recompute_rows(&st, $p.as_ref(), &mut values, rows)?;
                AnyRunResult { values: values.into(), stats }
            }};
        }
        Ok(match (app, baseline) {
            (AnyProgram::F32(p), AnyValues::F32(values)) => lane!(p, values),
            (AnyProgram::F64(p), AnyValues::F64(values)) => lane!(p, values),
            (AnyProgram::U32(p), AnyValues::U32(values)) => lane!(p, values),
            (AnyProgram::U64(p), AnyValues::U64(values)) => lane!(p, values),
            (app, values) => anyhow::bail!(
                "baseline values are on the {} lane but app {} runs on {}",
                values.lane().name(),
                app.name(),
                app.lane().name()
            ),
        })
    }

    /// The typed half of [`Self::run_any_rows`]: decode each affected
    /// shard once (through the cache, so repeated polls stay warm) and
    /// re-fold the listed rows against the program's init vector.
    fn recompute_rows<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &self,
        st: &EpochState,
        app: &P,
        values: &mut [V],
        rows: &[VertexId],
    ) -> Result<RunStats> {
        let t0 = Instant::now();
        let n = st.property.info.num_vertices as usize;
        anyhow::ensure!(
            values.len() == n,
            "baseline values cover {} vertices, dataset has {n}",
            values.len()
        );
        anyhow::ensure!(
            rows.iter().all(|&v| (v as usize) < n),
            "row set references vertices outside the dataset"
        );
        let ctx = ProgramContext { num_vertices: n as u64 };
        // iteration 0 of a single-pass run folds the init vector
        let src: Vec<V> = (0..n).map(|v| app.init(v as VertexId, &ctx)).collect();
        let out_deg = &st.vertex_info.degrees.out_deg;
        let mut by_shard: Vec<Vec<VertexId>> = vec![Vec::new(); st.property.num_shards()];
        for &v in rows {
            by_shard[st.property.shard_of(v)].push(v);
        }
        let mut stats = RunStats { load_wall: self.load_wall, ..Default::default() };
        for (shard, list) in by_shard.iter().enumerate() {
            if list.is_empty() {
                continue;
            }
            let admit = self.cfg.cache_budget > 0;
            let read = || match &self.direct {
                Some(r) => r.read_file(&st.shard_paths[shard]),
                None => io::read_file(&st.shard_paths[shard]),
            };
            let csr = self.cache.fetch_decoded(shard, st.shard_epochs[shard], admit, read)?;
            let (lo, hi) = st.property.interval(shard);
            anyhow::ensure!(
                csr.lo == lo && csr.num_vertices() == (hi - lo) as usize,
                "shard {shard} interval disagrees with property"
            );
            let delta = st.deltas[shard].as_deref();
            let mut out = [V::vzero()];
            for &v in list {
                let r = (v - lo) as usize;
                fold_chunk(
                    app,
                    CsrRows::new(&csr, r..r + 1),
                    delta,
                    r,
                    &src,
                    out_deg,
                    &ctx,
                    self.cfg.simd,
                    &mut out,
                )?;
                values[v as usize] = out[0];
            }
        }
        stats.total_wall = t0.elapsed();
        Ok(stats)
    }

    /// Run `app` to convergence (or the iteration cap): Algorithm 1.
    /// Generic over the program's value lane `V`; the edge weight lane (if
    /// the dataset carries one) reaches `gather` through the shard CSRs.
    pub fn run<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &self,
        app: &P,
    ) -> Result<RunResult<V>> {
        self.run_seeded(app, None)
    }

    /// [`Self::run`] against an explicit epoch snapshot (see
    /// [`Self::run_any_pinned`]).
    pub fn run_pinned<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &self,
        st: &Arc<EpochState>,
        app: &P,
    ) -> Result<RunResult<V>> {
        self.run_seeded_at(st, app, None)
    }

    /// [`Self::run`] with an optional warm start: instead of `init` +
    /// `initially_active`, begin from a prior fixpoint and a seeded active
    /// set.  With the seed being the sources of edges inserted since the
    /// fixpoint's epoch, a monotone (Min/Max) program re-converges
    /// incrementally: the old fixpoint over-approximates the new one and
    /// every relaxation the new edges enable starts at a seeded source.
    /// An empty seed converges in zero iterations (nothing changed).
    pub fn run_seeded<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &self,
        app: &P,
        warm: Option<WarmStart<V>>,
    ) -> Result<RunResult<V>> {
        self.run_seeded_at(&self.snapshot(), app, warm)
    }

    /// The engine loop proper, pinned to `st`.  Takes `&self` so any
    /// number of sessions can run concurrently against one engine: the
    /// worker pools are leased (first run gets the shared set, overlapping
    /// runs get a fresh throwaway set with identical thread counts — see
    /// [`Pools`]), and every cache access is keyed by `st.shard_epochs`.
    fn run_seeded_at<V: VertexValue, P: VertexProgram<V> + ?Sized>(
        &self,
        st: &Arc<EpochState>,
        app: &P,
        warm: Option<WarmStart<V>>,
    ) -> Result<RunResult<V>> {
        let t_run = Instant::now();
        let pools_guard = self.pools.try_lock();
        let pools_owned;
        let pools: &Pools = match pools_guard {
            Ok(ref g) => g,
            Err(_) => {
                pools_owned = Pools::build(&self.cfg);
                &pools_owned
            }
        };
        let n = st.property.info.num_vertices as usize;
        let p = st.property.num_shards();
        let ctx = ProgramContext { num_vertices: n as u64 };
        let max_iters = if self.cfg.max_iters > 0 {
            self.cfg.max_iters
        } else {
            app.default_max_iters()
        };
        obs::trace::record_run_start(app.name(), st.epoch);

        // init(src, dst) — line 1 (or the warm state verbatim)
        let (mut src, mut active): (Vec<V>, Vec<VertexId>) = match warm {
            Some(w) => {
                anyhow::ensure!(
                    w.values.len() == n,
                    "warm values cover {} vertices, dataset has {n}",
                    w.values.len()
                );
                let mut a = w.active;
                a.sort_unstable();
                a.dedup();
                anyhow::ensure!(
                    a.last().is_none_or(|&v| (v as usize) < n),
                    "warm active set references vertices outside the dataset"
                );
                (w.values, a)
            }
            None => (
                (0..n).map(|v| app.init(v as VertexId, &ctx)).collect(),
                (0..n as VertexId).filter(|&v| app.initially_active(v, &ctx)).collect(),
            ),
        };
        let mut dst = src.clone();
        let mut active_ratio = active.len() as f64 / n.max(1) as f64;

        let mut stats = RunStats {
            load_wall: self.load_wall,
            ..Default::default()
        };
        let mut edges_processed = 0u64;
        let out_deg = &st.vertex_info.degrees.out_deg;

        // persistent per-run state: worker scratch arenas, the digest
        // array, the active-merge staging and the payload-buffer freelist
        // are allocated once here and reused by every iteration — the
        // zero-allocation steady state
        let mut scratch: Vec<WorkerScratch> =
            (0..pools.compute.threads()).map(|_| WorkerScratch::default()).collect();
        let mut digest_buf: Vec<Digest> = Vec::new();
        let mut next_active: Vec<VertexId> = Vec::new();
        let mut run_index: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
        let native = matches!(self.cfg.backend, Backend::Native);
        let use_stream = native && self.cfg.stream_gather;
        let chunk_rows = if self.cfg.chunk_rows == 0 { usize::MAX } else { self.cfg.chunk_rows };
        let buf_pool = BufPool::new();

        for iter in 0..max_iters {
            if active.is_empty() {
                break; // line 2: ratio == 0
            }
            let t_iter = Instant::now();
            let io_before = io::snapshot();
            let hits_before = self.cache.stats.hits.load(Ordering::Relaxed);
            let miss_before = self.cache.stats.misses.load(Ordering::Relaxed);
            let kernels_before = match &self.cfg.backend {
                Backend::Xla(rt) => rt.call_count(),
                Backend::Native => 0,
            };

            // selective scheduling engages under the threshold — line 5
            let selective_now = self.cfg.selective
                && active_ratio > 0.0
                && active_ratio < self.cfg.selective_threshold;

            // hash each active vertex exactly once; every Bloom probe this
            // iteration — per-shard screening *and* the governor's density
            // scoring — reuses this digest array instead of re-hashing the
            // active set once per shard (the old O(shards × |active| × k)
            // screening cost, now O(|active|) hashes + cheap derivations)
            digest_buf.clear();
            if selective_now {
                digest_buf.extend(active.iter().map(|&v| digest(v as u64)));
            }
            let digests: &[Digest] = &digest_buf;

            // governor: size this iteration's in-flight window (a finite
            // cache budget lends its unused bytes; an unbounded or disabled
            // cache imposes no loan) and pick the shard issue order
            let mut lent_bytes = 0usize;
            let window = if pools.io.is_some() {
                let lendable =
                    if self.cfg.cache_budget == 0 || self.cfg.cache_budget == usize::MAX {
                        None
                    } else {
                        let l = self.cache.lendable_bytes();
                        lent_bytes = l;
                        Some(l)
                    };
                self.governor.plan_window(lendable)
            } else {
                0
            };
            // direct-I/O path: the governor's in-flight window IS the
            // device queue depth — feed it to the submission ring so
            // adaptive widening/narrowing reaches the hardware
            if window > 0 {
                if let Some(r) = &self.direct {
                    r.set_queue_depth(window);
                }
            }
            let order = if pools.io.is_some() {
                self.governor.schedule(
                    p,
                    selective_now,
                    digests,
                    &st.blooms,
                    &self.cache,
                    &st.shard_epochs,
                )
            } else {
                Vec::new()
            };

            let processed = AtomicU64::new(0);
            let skipped = AtomicU64::new(0);
            let edge_count = AtomicU64::new(0);
            let io_wait_ns = AtomicU64::new(0);
            let compute_ns = AtomicU64::new(0);
            let decode_ns = AtomicU64::new(0);
            let err_slot: Mutex<Option<anyhow::Error>> = Mutex::new(None);

            {
                let dst_shared = SharedSlice::new(&mut dst);
                let src_ref: &[V] = &src;
                let cfg = &self.cfg;
                let blooms = &st.blooms;
                let cache = &self.cache;
                let shard_paths = &st.shard_paths;
                let shard_epochs = &st.shard_epochs;
                let deltas = &st.deltas;
                let property = &st.property;
                let tol = cfg.convergence_tol;
                let buf_pool = &buf_pool;
                let decode_ns = &decode_ns;

                // -- per-shard pieces shared by both paths ----------------
                let record_err = |e: anyhow::Error| {
                    let mut slot = err_slot.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                };
                // line 5: is the shard provably inactive?  One digest per
                // active vertex, computed above, serves all P probes.
                let screened_out =
                    |shard: usize| selective_now && !blooms[shard].contains_any_digest(digests);
                // carry values of an untouched interval forward (counted
                // as skipped at finalize time)
                let carry_skipped = |shard: usize| {
                    let (lo, hi) = property.interval(shard);
                    unsafe {
                        dst_shared.write_range(lo as usize, &src_ref[lo as usize..hi as usize]);
                    }
                };
                // row range of chunk `c` in a `rows`-wide shard
                let chunk_range = move |rows: usize, c: usize| {
                    let a = c.saturating_mul(chunk_rows).min(rows);
                    let b = a.saturating_add(chunk_rows).min(rows);
                    (a, b)
                };
                let chunks_of =
                    move |rows: usize| if native { rows.div_ceil(chunk_rows).max(1) } else { 1 };
                // in-place writes through `slice_mut` rely on the shard
                // staying inside its property interval — reject a payload
                // that disagrees before any chunk touches `dst`
                let check_interval = |shard: usize, lo: u32, rows: usize| -> Result<()> {
                    let (plo, phi) = property.interval(shard);
                    anyhow::ensure!(
                        lo == plo && rows == (phi - plo) as usize,
                        "shard {shard} interval [{lo}, +{rows}) disagrees with property \
                         [{plo},{phi})"
                    );
                    Ok(())
                };
                // line 6: load_to_memory(shard) — cache first, then disk.
                // Builds the shard's board entry: the cheapest faithful
                // representation (decoded Arc, in-place payload view, or
                // delta-varint stream) plus its chunk split.  Decode work
                // not fused into the gather (payload decompression, dv
                // planning, layout validation) is timed into `decode_ns`.
                // effective per-shard edge count with the resident delta
                // folded in (pure stats; the merge itself happens row by
                // row inside the gather fold)
                let eff_edges = |shard: usize, base: u64| match deltas[shard].as_ref() {
                    Some(d) => d.effective_edges(base),
                    None => base,
                };
                let direct = &self.direct;
                let acquire = |shard: usize, did_read: &Cell<bool>| -> ShardWork {
                    // flight-recorder span inputs: wall acquire time and the
                    // slice of it spent decoding (Cell because the decode
                    // sites live inside the payload-builder closure)
                    let t_acq = Instant::now();
                    let dec_local = Cell::new(0u64);
                    let admit = cfg.cache_budget > 0;
                    let read = || {
                        did_read.set(true);
                        match direct {
                            Some(r) => r.read_file(&shard_paths[shard]),
                            None => io::read_file(&shard_paths[shard]),
                        }
                    };
                    let built: Result<(WorkPayload, usize, u64)> = (|| {
                        if !use_stream {
                            let mut csr =
                                cache.fetch_decoded(shard, shard_epochs[shard], admit, read)?;
                            check_interval(shard, csr.lo, csr.num_vertices())?;
                            let edges = eff_edges(shard, csr.num_edges() as u64);
                            // the xla path runs whole-shard kernels over a
                            // decoded CSR; materialize the merged shard for
                            // it (native wraps the stream instead).  The
                            // merge is O(shard edges) per acquisition and
                            // not memoized — acceptable while xla is the
                            // artifact-gated side path; memoize per epoch
                            // if that changes (ROADMAP follow-on)
                            if !native {
                                if let Some(d) = deltas[shard].as_ref() {
                                    csr = Arc::new(d.merge(&csr));
                                }
                            }
                            let chunks = chunks_of(csr.num_vertices());
                            return Ok((WorkPayload::Decoded(csr), chunks, edges));
                        }
                        match cache.fetch_view(shard, shard_epochs[shard], admit, read)? {
                            ShardView::Decoded(csr) => {
                                check_interval(shard, csr.lo, csr.num_vertices())?;
                                let chunks = chunks_of(csr.num_vertices());
                                let edges = eff_edges(shard, csr.num_edges() as u64);
                                Ok((WorkPayload::Decoded(csr), chunks, edges))
                            }
                            ShardView::Raw(bytes) => {
                                let t0 = Instant::now();
                                let layout = shardfile::parse_layout(&bytes)?;
                                let d = t0.elapsed().as_nanos() as u64;
                                decode_ns.fetch_add(d, Ordering::Relaxed);
                                dec_local.set(dec_local.get() + d);
                                check_interval(shard, layout.lo, layout.num_rows())?;
                                let chunks = chunks_of(layout.num_rows());
                                let edges = eff_edges(shard, layout.num_edges as u64);
                                Ok((
                                    WorkPayload::View { bytes, layout, pooled: false },
                                    chunks,
                                    edges,
                                ))
                            }
                            ShardView::Compressed { codec: Codec::DeltaVarint, bytes } => {
                                // planned per hit: the plan pass doubles as
                                // the payload's integrity check (exactly
                                // what decode validated before), costs two
                                // allocation-free varint sweeps, and buys
                                // chunk-parallel decoding — still strictly
                                // cheaper than the decoded path's
                                // three-vector materialization per hit
                                let t0 = Instant::now();
                                let plan = deltavarint::plan(&bytes, chunk_rows)?;
                                let d = t0.elapsed().as_nanos() as u64;
                                decode_ns.fetch_add(d, Ordering::Relaxed);
                                dec_local.set(dec_local.get() + d);
                                check_interval(shard, plan.lo, plan.num_rows)?;
                                let chunks = plan.chunks.len();
                                let edges = eff_edges(shard, plan.num_edges as u64);
                                Ok((WorkPayload::Dv { bytes, plan }, chunks, edges))
                            }
                            ShardView::Compressed { codec, bytes } => {
                                let t0 = Instant::now();
                                let mut buf = buf_pool.take();
                                codec.decompress_payload_into(&bytes, &mut buf)?;
                                let layout = shardfile::parse_layout(&buf)?;
                                let d = t0.elapsed().as_nanos() as u64;
                                decode_ns.fetch_add(d, Ordering::Relaxed);
                                dec_local.set(dec_local.get() + d);
                                check_interval(shard, layout.lo, layout.num_rows())?;
                                let chunks = chunks_of(layout.num_rows());
                                let edges = eff_edges(shard, layout.num_edges as u64);
                                Ok((
                                    WorkPayload::View {
                                        bytes: Arc::new(buf),
                                        layout,
                                        pooled: true,
                                    },
                                    chunks,
                                    edges,
                                ))
                            }
                        }
                    })();
                    match built {
                        Ok((payload, chunks, edges)) => {
                            let mut w = ShardWork::new(shard, payload, chunks, edges);
                            w.acquire_ns = t_acq.elapsed().as_nanos() as u64;
                            w.decode_local_ns = dec_local.get();
                            w
                        }
                        Err(e) => {
                            record_err(e);
                            ShardWork::new(shard, WorkPayload::Failed, 1, 0)
                        }
                    }
                };
                // record a chunk's newly-active vertices into the worker's
                // arena (merged deterministically after the phase)
                let scan_active =
                    |s: &mut WorkerScratch, shard: usize, chunk: usize, base: usize, out: &[V]| {
                        let start = s.active.len();
                        for (i, &nv) in out.iter().enumerate() {
                            if V::changed(src_ref[base + i], nv, tol as f64) {
                                s.active.push((base + i) as VertexId);
                            }
                        }
                        let len = s.active.len() - start;
                        if len > 0 {
                            s.runs.push((shard, chunk, start, len));
                        }
                    };
                // lines 7-9 for one chunk: stream the rows through the
                // backend straight into `dst` (no per-shard value vector),
                // then scan the written range for activity
                let process_chunk = |s: &mut WorkerScratch, work: &ShardWork, chunk: usize| {
                    // resident delta merged into the row stream (native
                    // paths); the xla path received a merged CSR at acquire
                    let delta = deltas[work.shard].as_deref();
                    match &work.payload {
                        WorkPayload::Skipped => carry_skipped(work.shard),
                        WorkPayload::Failed => {}
                        WorkPayload::Decoded(csr) => {
                            let lo = csr.lo as usize;
                            if native {
                                let (a, b) = chunk_range(csr.num_vertices(), chunk);
                                let out = unsafe { dst_shared.slice_mut(lo + a, b - a) };
                                let rows = CsrRows::new(csr, a..b);
                                match fold_chunk(
                                    app, rows, delta, a, src_ref, out_deg, &ctx, cfg.simd, out,
                                ) {
                                    Ok(()) => scan_active(s, work.shard, chunk, lo + a, out),
                                    Err(e) => record_err(e),
                                }
                            } else {
                                // xla path: whole-shard kernels, one chunk
                                match cfg.backend.process_shard(app, csr, src_ref, out_deg, &ctx)
                                {
                                    Ok(new_vals) => {
                                        unsafe { dst_shared.write_range(lo, &new_vals) };
                                        scan_active(s, work.shard, chunk, lo, &new_vals);
                                    }
                                    Err(e) => record_err(e),
                                }
                            }
                        }
                        WorkPayload::View { bytes, layout, .. } => {
                            let lo = layout.lo as usize;
                            let (a, b) = chunk_range(layout.num_rows(), chunk);
                            let out = unsafe { dst_shared.slice_mut(lo + a, b - a) };
                            let rows = ViewRows::new(layout.view(bytes), a..b);
                            match fold_chunk(
                                app, rows, delta, a, src_ref, out_deg, &ctx, cfg.simd, out,
                            ) {
                                Ok(()) => scan_active(s, work.shard, chunk, lo + a, out),
                                Err(e) => record_err(e),
                            }
                        }
                        WorkPayload::Dv { bytes, plan } => {
                            let dv = &plan.chunks[chunk];
                            let lo = plan.lo as usize;
                            let (a, b) = (dv.start_row, dv.end_row);
                            let out = unsafe { dst_shared.slice_mut(lo + a, b - a) };
                            let rows = DvRows::new(plan.cursor(bytes, dv), plan.lo, a, b - a);
                            match fold_chunk(
                                app, rows, delta, a, src_ref, out_deg, &ctx, cfg.simd, out,
                            ) {
                                Ok(()) => scan_active(s, work.shard, chunk, lo + a, out),
                                Err(e) => record_err(e),
                            }
                        }
                    }
                };
                // shard bookkeeping once its last chunk lands (pooled
                // payload buffers go back to the freelist here)
                let finalize = |work: &ShardWork| match &work.payload {
                    WorkPayload::Skipped => {
                        skipped.fetch_add(1, Ordering::Relaxed);
                    }
                    WorkPayload::Failed => {}
                    other => {
                        processed.fetch_add(1, Ordering::Relaxed);
                        edge_count.fetch_add(work.edges, Ordering::Relaxed);
                        if let WorkPayload::View { bytes, pooled: true, .. } = other {
                            buf_pool.put(bytes.clone());
                        }
                        if obs::trace::shard_sampled(work.shard as u64) {
                            obs::trace::record(obs::trace::TraceRecord::Shard {
                                iter: iter as u64,
                                shard: work.shard as u64,
                                acquire_ns: work.acquire_ns,
                                decode_ns: work.decode_local_ns,
                                fold_ns: work.fold_ns.load(Ordering::Relaxed),
                            });
                        }
                    }
                };

                if let Some(io_pool) = pools.io.as_ref().filter(|_| window > 0) {
                    // ---- pipelined path: the I/O pool produces ready
                    // shards (hottest first, per the governor's schedule)
                    // onto the chunk board; every compute worker claims
                    // chunk-sized pieces off the board, so a wide shard
                    // spans cores instead of serializing the iteration
                    // tail.  At most `window` permit-holding shards are in
                    // flight at once. -------------------------------------
                    let gate = &Semaphore::new(window);
                    let board = &ChunkBoard::new(p);
                    let adaptive = self.governor.is_adaptive();
                    let scratch_ref: &mut [WorkerScratch] = &mut scratch;
                    std::thread::scope(|scope| {
                        let screened_out = &screened_out;
                        let acquire = &acquire;
                        let record_err = &record_err;
                        let order = &order;
                        scope.spawn(move || {
                            io_pool.parallel_for(p, |k| {
                                let shard = order[k];
                                if screened_out(shard) {
                                    board.push(ShardWork::new(shard, WorkPayload::Skipped, 1, 0));
                                    return;
                                }
                                // in-flight budget — except that a cache
                                // hit that materializes no decoded bytes
                                // (mode-1's Arc clone; delta-varint under
                                // the compressed-domain gather, which
                                // streams straight from the slot payload)
                                // never waits for a read-ahead slot (it
                                // still takes a free one opportunistically).
                                // Byte codecs decompress a payload-sized
                                // buffer per hit — exactly the memory the
                                // window bounds — so they stay gated.
                                let resident_streams = cache.codec() == Codec::None
                                    || (use_stream && cache.codec() == Codec::DeltaVarint);
                                let fast_resident = adaptive
                                    && resident_streams
                                    && cache.is_resident(shard, shard_epochs[shard]);
                                let mut holds_permit = if fast_resident {
                                    gate.try_acquire()
                                } else {
                                    gate.acquire();
                                    true
                                };
                                // a panic inside acquisition (e.g. a
                                // poisoned cache lock) must not kill the
                                // pool worker — that would starve the
                                // board; surface it as a Failed entry
                                let did_read = Cell::new(false);
                                let mut work = match std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| acquire(shard, &did_read)),
                                ) {
                                    Ok(work) => work,
                                    Err(_) => {
                                        record_err(anyhow::anyhow!(
                                            "shard {shard} acquisition panicked"
                                        ));
                                        ShardWork::new(shard, WorkPayload::Failed, 1, 0)
                                    }
                                };
                                // the resident-bypass raced an eviction and
                                // the shard came off disk after all: take
                                // the in-flight permit it owes before
                                // publishing, so the decoded envelope holds
                                if !holds_permit && did_read.get() {
                                    gate.acquire();
                                    holds_permit = true;
                                }
                                work.permit = holds_permit;
                                board.push(work);
                            });
                        });
                        pools.compute.broadcast_with(scratch_ref, |s, _worker| loop {
                            let t_wait = Instant::now();
                            let claimed = board.claim();
                            let waited = t_wait.elapsed().as_nanos() as u64;
                            // the terminal wait (claim -> None while peers
                            // drain the tail) is bookkeeping, not an I/O
                            // stall: counting it would overstate
                            // io_wait_fraction and mislead the governor
                            // toward growing the window on compute-bound
                            // iterations
                            let Some((work, chunk)) = claimed else { break };
                            io_wait_ns.fetch_add(waited, Ordering::Relaxed);
                            let t_comp = Instant::now();
                            process_chunk(s, &work, chunk);
                            let dt = t_comp.elapsed().as_nanos() as u64;
                            compute_ns.fetch_add(dt, Ordering::Relaxed);
                            work.fold_ns.fetch_add(dt, Ordering::Relaxed);
                            if work.done_chunks.fetch_add(1, Ordering::AcqRel) + 1
                                == work.num_chunks
                            {
                                finalize(&work);
                                if work.permit {
                                    gate.release();
                                }
                                board.finalized();
                            }
                        });
                    });
                } else {
                    // ---- synchronous path (prefetch_depth = 0): workers
                    // acquire and process whole shards off a shared cursor,
                    // chunk by chunk, with the same scratch arenas --------
                    let cursor = AtomicUsize::new(0);
                    pools.compute.broadcast_with(&mut scratch, |s, _worker| loop {
                        let shard = cursor.fetch_add(1, Ordering::Relaxed);
                        if shard >= p {
                            break;
                        }
                        if screened_out(shard) {
                            carry_skipped(shard);
                            skipped.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        let t_io = Instant::now();
                        let did_read = Cell::new(false);
                        let work = acquire(shard, &did_read);
                        io_wait_ns.fetch_add(t_io.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        let t_comp = Instant::now();
                        for chunk in 0..work.num_chunks {
                            process_chunk(s, &work, chunk);
                        }
                        work.fold_ns
                            .fetch_add(t_comp.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        finalize(&work);
                        compute_ns.fetch_add(t_comp.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    });
                }
            }
            if let Some(e) = err_slot.into_inner().unwrap() {
                return Err(e);
            }

            // lines 9-11: merge the workers' active runs in deterministic
            // (shard, chunk) order — each (shard, chunk) was processed by
            // exactly one worker, so the sorted run list reproduces the
            // per-shard ascending order regardless of scheduling — then
            // swap arrays and recompute the ratio.  The staging buffers
            // persist across iterations: no allocation in steady state.
            run_index.clear();
            for (w, s) in scratch.iter().enumerate() {
                for &(shard, chunk, start, len) in &s.runs {
                    run_index.push((shard, chunk, w, start, len));
                }
            }
            run_index.sort_unstable();
            next_active.clear();
            for &(_, _, w, start, len) in &run_index {
                next_active.extend_from_slice(&scratch[w].active[start..start + len]);
            }
            for s in scratch.iter_mut() {
                s.active.clear();
                s.runs.clear();
            }
            std::mem::swap(&mut active, &mut next_active);
            active_ratio = active.len() as f64 / n.max(1) as f64;
            std::mem::swap(&mut src, &mut dst);

            // feedback: the governor only ever sees *completed* iterations,
            // so its next decision is a pure function of prior work
            self.governor.observe(
                io_wait_ns.load(Ordering::Relaxed),
                compute_ns.load(Ordering::Relaxed),
            );

            edges_processed += edge_count.load(Ordering::Relaxed);
            stats.iters.push(IterStats {
                iter,
                wall: t_iter.elapsed(),
                shards_processed: processed.load(Ordering::Relaxed) as usize,
                shards_skipped: skipped.load(Ordering::Relaxed) as usize,
                active_vertices: active.len() as u64,
                active_ratio,
                io: io::snapshot().since(&io_before),
                cache_hits: self.cache.stats.hits.load(Ordering::Relaxed) - hits_before,
                cache_misses: self.cache.stats.misses.load(Ordering::Relaxed) - miss_before,
                kernel_calls: match &self.cfg.backend {
                    Backend::Xla(rt) => rt.call_count() - kernels_before,
                    Backend::Native => 0,
                },
                selective_enabled: selective_now,
                io_wait: std::time::Duration::from_nanos(io_wait_ns.load(Ordering::Relaxed)),
                compute: std::time::Duration::from_nanos(compute_ns.load(Ordering::Relaxed)),
                prefetch_depth: window,
                decode_ns: decode_ns.load(Ordering::Relaxed),
            });

            // observability: one registry push + one flight-recorder record
            // per completed iteration (no-ops under GRAPHMP_OBS=0; proven
            // bit-invisible by tests/obs_conformance.rs)
            let it = stats.iters.last().expect("just pushed");
            if obs::metrics::enabled() {
                self.obs_iteration(st, it, lent_bytes);
            }
            if obs::trace::installed() {
                obs::trace::record(obs::trace::TraceRecord::Iter {
                    epoch: st.epoch,
                    iter: iter as u64,
                    wall_ns: it.wall.as_nanos() as u64,
                    io_wait_ns: it.io_wait.as_nanos() as u64,
                    compute_ns: it.compute.as_nanos() as u64,
                    decode_ns: it.decode_ns,
                    shards_processed: it.shards_processed as u64,
                    shards_skipped: it.shards_skipped as u64,
                    active: it.active_vertices,
                    read_bytes: it.io.bytes_read,
                    cache_hits: it.cache_hits,
                    cache_misses: it.cache_misses,
                    window: window as u64,
                });
            }
        }

        stats.total_wall = t_run.elapsed();
        stats.edges_processed = edges_processed;
        stats.memory_bytes = self.memory_estimate_for(st);
        Ok(RunResult { values: src, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{PageRank, Sssp, Wcc};
    use crate::graph::generator;
    use crate::sharding::{preprocess, PreprocessConfig};

    fn build_dataset(tag: &str, edges: &[(u32, u32)], n: usize, shard_cap: usize) -> DatasetDir {
        let dir = DatasetDir::new(
            std::env::temp_dir().join(format!("gmp_vsw_{tag}_{}", std::process::id())),
        );
        let _ = std::fs::remove_dir_all(&dir.root);
        let cfg = PreprocessConfig { max_edges_per_shard: shard_cap, bloom_fpr: 0.01 };
        preprocess(tag, edges, n, &dir, &cfg).unwrap();
        dir
    }

    /// Single-threaded reference implementation of the whole program.
    fn reference_run(
        app: &dyn VertexProgram,
        edges: &[(u32, u32)],
        n: usize,
        max_iters: usize,
    ) -> Vec<f32> {
        let ctx = ProgramContext { num_vertices: n as u64 };
        let mut in_adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut out_deg = vec![0u32; n];
        for &(s, d) in edges {
            in_adj[d as usize].push(s);
            out_deg[s as usize] += 1;
        }
        let mut vals: Vec<f32> = (0..n).map(|v| app.init(v as u32, &ctx)).collect();
        for _ in 0..max_iters {
            let next: Vec<f32> = (0..n)
                .map(|v| app.update(v as u32, &in_adj[v], &vals, &out_deg, &ctx))
                .collect();
            let changed = next
                .iter()
                .zip(&vals)
                .any(|(a, b)| !(a.is_infinite() && b.is_infinite()) && a != b);
            vals = next;
            if !changed {
                break;
            }
        }
        vals
    }

    #[test]
    fn pagerank_matches_reference() {
        let edges = generator::rmat(8, 2000, generator::RmatParams::default(), 1);
        let n = 256;
        let dir = build_dataset("pr", &edges, n, 300);
        let engine = VswEngine::open(
            dir,
            EngineConfig { max_iters: 10, threads: 4, ..Default::default() },
        )
        .unwrap();
        let result = engine.run(&PageRank::default()).unwrap();
        let want = reference_run(&PageRank::default(), &edges, n, 10);
        for (i, (a, b)) in result.values.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-5, "v{i}: {a} vs {b}");
        }
        assert!(result.stats.num_iters() <= 10);
    }

    #[test]
    fn sssp_and_wcc_converge_to_reference() {
        let edges = generator::erdos_renyi(300, 1500, 3);
        let n = 300;
        let dir = build_dataset("minapps", &edges, n, 256);
        let engine =
            VswEngine::open(dir, EngineConfig { threads: 3, ..Default::default() }).unwrap();

        let sssp = Sssp { source: 0 };
        let got = engine.run(&sssp).unwrap();
        let want = reference_run(&sssp, &edges, n, 1000);
        for (i, (a, b)) in got.values.iter().zip(&want).enumerate() {
            assert!(
                (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-6,
                "sssp v{i}: {a} vs {b}"
            );
        }

        let got = engine.run(&Wcc).unwrap();
        let want = reference_run(&Wcc, &edges, n, 1000);
        assert_eq!(got.values, want, "wcc fixpoint");
    }

    #[test]
    fn selective_scheduling_skips_shards_and_preserves_results() {
        // SSSP on a long path: after the frontier passes, shards go inactive
        let n = 400;
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|v| (v, v + 1)).collect();
        let dir = build_dataset("sel", &edges, n, 32);
        // threshold 0.05: the SSSP frontier on a path is 1 vertex (ratio
        // 1/400 = 0.0025), comfortably below it from iteration 1 on
        let on = VswEngine::open(
            dir.clone(),
            EngineConfig {
                selective: true,
                selective_threshold: 0.05,
                threads: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let off = VswEngine::open(
            dir,
            EngineConfig { selective: false, threads: 2, ..Default::default() },
        )
        .unwrap();
        let app = Sssp { source: 0 };
        let a = on.run(&app).unwrap();
        let b = off.run(&app).unwrap();
        assert_eq!(a.values, b.values, "selective must not change results");
        let skipped: usize = a.stats.iters.iter().map(|i| i.shards_skipped).sum();
        assert!(skipped > 0, "no shards were skipped");
        let skipped_off: usize = b.stats.iters.iter().map(|i| i.shards_skipped).sum();
        assert_eq!(skipped_off, 0);
    }

    #[test]
    fn cache_disabled_reads_disk_every_iteration() {
        let edges = generator::erdos_renyi(128, 1000, 9);
        let dir = build_dataset("nocache", &edges, 128, 128);
        let nc = VswEngine::open(
            dir.clone(),
            EngineConfig { cache_budget: 0, max_iters: 3, selective: false, ..Default::default() },
        )
        .unwrap();
        let result = nc.run(&PageRank::default()).unwrap();
        // every iteration must re-read every shard from disk
        for it in &result.stats.iters {
            assert!(it.io.bytes_read > 0, "iter {} read nothing", it.iter);
            assert_eq!(it.cache_hits, 0);
        }
        // cached engine: zero disk reads after warmup
        let c = VswEngine::open(
            dir,
            EngineConfig { max_iters: 3, selective: false, ..Default::default() },
        )
        .unwrap();
        let result = c.run(&PageRank::default()).unwrap();
        for it in &result.stats.iters {
            assert_eq!(it.io.bytes_read, 0, "iter {} hit disk despite cache", it.iter);
            assert!(it.cache_hits > 0);
        }
    }

    #[test]
    fn memory_estimate_scales_with_cache() {
        let edges = generator::erdos_renyi(200, 3000, 4);
        let dir = build_dataset("mem", &edges, 200, 512);
        let nc = VswEngine::open(
            dir.clone(),
            EngineConfig { cache_budget: 0, ..Default::default() },
        )
        .unwrap();
        let c = VswEngine::open(dir, EngineConfig::default()).unwrap();
        assert!(c.memory_estimate() > nc.memory_estimate());
    }

    #[test]
    fn pipelined_and_synchronous_paths_agree() {
        let edges = generator::rmat(9, 6000, generator::RmatParams::default(), 12);
        let n = 512;
        let dir = build_dataset("pipe", &edges, n, 400);
        let run = |depth: usize| {
            let engine = VswEngine::open(
                dir.clone(),
                EngineConfig {
                    max_iters: 6,
                    threads: 4,
                    prefetch_depth: depth,
                    cache_budget: 0, // force real disk traffic through the pipeline
                    selective: false,
                    ..Default::default()
                },
            )
            .unwrap();
            engine.run(&PageRank::default()).unwrap()
        };
        let sync = run(0);
        for depth in [1usize, 3, 8] {
            let piped = run(depth);
            assert_eq!(sync.values, piped.values, "depth {depth} changed results");
            assert_eq!(
                sync.stats.iters.len(),
                piped.stats.iters.len(),
                "depth {depth} changed iteration count"
            );
            for (a, b) in sync.stats.iters.iter().zip(&piped.stats.iters) {
                assert_eq!(a.shards_processed, b.shards_processed, "depth {depth}");
                assert_eq!(a.shards_skipped, b.shards_skipped, "depth {depth}");
            }
        }
    }

    #[test]
    fn adaptive_governor_preserves_results_and_reports_window() {
        let edges = generator::rmat(9, 5000, generator::RmatParams::default(), 7);
        let n = 512;
        let dir = build_dataset("gov", &edges, n, 400);
        let fixed = VswEngine::open(
            dir.clone(),
            EngineConfig { max_iters: 6, threads: 4, prefetch_depth: 2, ..Default::default() },
        )
        .unwrap();
        let adaptive = VswEngine::open(
            dir,
            EngineConfig {
                max_iters: 6,
                threads: 4,
                prefetch_depth: 2,
                adaptive: true,
                prefetch_max: 8,
                ..Default::default()
            },
        )
        .unwrap();
        let a = fixed.run(&PageRank::default()).unwrap();
        let b = adaptive.run(&PageRank::default()).unwrap();
        assert_eq!(a.values, b.values, "governor must not change results");
        for it in &b.stats.iters {
            assert!(
                (1..=8).contains(&it.prefetch_depth),
                "iter {} window {} outside [1, max]",
                it.iter,
                it.prefetch_depth
            );
        }
        // the memory estimate must account the window high-water, which the
        // governor tracks and can never undershoot the planned windows
        assert!(adaptive.governor().high_water() >= b.stats.max_prefetch_depth());
        assert!(adaptive.governor().high_water() >= 1);
        // fixed engine: high-water == configured depth, estimate unchanged
        assert_eq!(fixed.governor().high_water(), 2);
    }

    #[test]
    fn compressed_domain_and_chunking_are_bit_identical() {
        // the tentpole's acceptance bar, at unit scope: every codec ×
        // stream on/off × several chunk sizes must reproduce the exact
        // value bits and shard accounting of the legacy configuration
        let edges = generator::rmat(9, 6000, generator::RmatParams::default(), 31);
        let n = 512;
        let dir = build_dataset("stream", &edges, n, 300);
        let run = |codec: Codec, stream: bool, chunk_rows: usize, depth: usize| {
            let engine = VswEngine::open(
                dir.clone(),
                EngineConfig {
                    max_iters: 5,
                    threads: 4,
                    cache_codec: codec,
                    stream_gather: stream,
                    chunk_rows,
                    prefetch_depth: depth,
                    selective: false,
                    ..Default::default()
                },
            )
            .unwrap();
            engine.run(&PageRank::default()).unwrap()
        };
        for codec in [Codec::None, Codec::SnapLite, Codec::Zlib1, Codec::DeltaVarint] {
            // golden is per-codec (decode path, no chunk splitting,
            // synchronous): delta-varint normalizes row order, which
            // legitimately reorders float-Sum folds vs the byte codecs —
            // but within a codec every knob must be bit-invisible
            let golden = run(codec, false, 0, 0);
            let golden_bits: Vec<u32> = golden.values.iter().map(|v| v.to_bits()).collect();
            for stream in [false, true] {
                for chunk_rows in [0usize, 7, 64, 8192] {
                    for depth in [0usize, 2] {
                        let got = run(codec, stream, chunk_rows, depth);
                        let bits: Vec<u32> = got.values.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(
                            golden_bits, bits,
                            "codec={} stream={stream} chunk_rows={chunk_rows} depth={depth}",
                            codec.name()
                        );
                        assert_eq!(golden.stats.iters.len(), got.stats.iters.len());
                        for (a, b) in golden.stats.iters.iter().zip(&got.stats.iters) {
                            assert_eq!(a.shards_processed, b.shards_processed);
                            assert_eq!(a.shards_skipped, b.shards_skipped);
                        }
                    }
                }
            }
        }
        // the compressed-domain path is the default and reports its decode
        // split for compressing codecs on the pipelined path
        let dv = run(Codec::DeltaVarint, true, 64, 2);
        assert!(
            dv.stats.total_decode_ns() > 0,
            "dv planning must land in the decode_ns lane"
        );
    }

    #[test]
    fn typed_lanes_and_weights_run_end_to_end() {
        use crate::apps::{AnyProgram, LabelProp, MaxDeg, WeightedSssp};
        use crate::sharding::preprocess_weighted;
        // weighted path 0 -(0.5)-> 1 -(0.25)-> 2 -(2.0)-> 3, heavy shortcut
        let edges = vec![(0u32, 1u32), (1, 2), (2, 3), (0, 3)];
        let weights = vec![0.5f32, 0.25, 2.0, 9.0];
        let n = 4;
        let dir = DatasetDir::new(
            std::env::temp_dir().join(format!("gmp_vsw_typed_{}", std::process::id())),
        );
        let _ = std::fs::remove_dir_all(&dir.root);
        let cfg = PreprocessConfig { max_edges_per_shard: 2, bloom_fpr: 0.01 };
        preprocess_weighted("typed", &edges, &weights, n, &dir, &cfg).unwrap();
        let engine =
            VswEngine::open(dir, EngineConfig { threads: 2, ..Default::default() }).unwrap();

        // f32 over the weight lane
        let w = engine.run(&WeightedSssp { source: 0 }).unwrap();
        assert_eq!(w.values, vec![0.0, 0.5, 0.75, 2.75]);

        // u64 min-label lane
        let lp: &dyn VertexProgram<u64> = &LabelProp;
        let l = engine.run(lp).unwrap();
        assert_eq!(l.values, vec![0, 0, 0, 0]);

        // u32 max lane: out_deg = [2,1,1,0]; every downstream vertex sees 2
        let md: &dyn VertexProgram<u32> = &MaxDeg;
        let m = engine.run(md).unwrap();
        assert_eq!(m.values, vec![0, 2, 2, 2]);

        // the lane-erased CLI path agrees with the typed one
        let any = AnyProgram::U32(Box::new(MaxDeg));
        let a = engine.run_any(&any).unwrap();
        assert_eq!(a.values, crate::graph::AnyValues::U32(m.values));
    }

    #[test]
    fn ingest_then_refresh_sees_new_epoch_and_compaction_invalidates_slots() {
        use crate::graph::mutation::{self, Mutation};
        let edges = generator::erdos_renyi(128, 900, 21);
        let dir = build_dataset("epoch", &edges, 128, 128);
        let engine = VswEngine::open(
            dir.clone(),
            EngineConfig { threads: 2, selective: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(engine.epoch(), 0);
        let before = engine.run(&Wcc).unwrap();

        // mutate: bridge vertex 0 into everything reachable from 100
        let batch = vec![
            Mutation::Insert { src: 0, dst: 100, weight: 1.0 },
            Mutation::Insert { src: 100, dst: 0, weight: 1.0 },
        ];
        mutation::ingest(&dir, &batch, 0.01).unwrap();
        // the live engine still reads epoch 0 until refreshed
        let still = engine.run(&Wcc).unwrap();
        assert_eq!(before.values, still.values, "pre-refresh reads stay at the old epoch");
        assert_eq!(engine.refresh_latest().unwrap(), 1);
        let after = engine.run(&Wcc).unwrap();
        // the new edges can only merge components (labels never rise)
        assert!(after
            .values
            .iter()
            .zip(&before.values)
            .all(|(a, b)| a <= b));

        // a from-scratch rebuild of the mutated graph agrees exactly
        let (mut final_edges, mut w) = (edges.clone(), Vec::new());
        mutation::apply_batch(&mut final_edges, &mut w, &batch).unwrap();
        let dir2 = build_dataset("epoch_rebuild", &final_edges, 128, 128);
        let rebuilt = VswEngine::open(
            dir2,
            EngineConfig { threads: 2, selective: false, ..Default::default() },
        )
        .unwrap()
        .run(&Wcc)
        .unwrap();
        assert_eq!(after.values, rebuilt.values, "delta-merged != from-scratch");

        // compaction rewrites base files; refresh invalidates exactly the
        // touched slots and results stay bit-identical
        let r = mutation::compact(&dir, 0.0).unwrap();
        assert!(r.epoch.is_some());
        assert_eq!(engine.refresh_latest().unwrap(), 2);
        let compacted = engine.run(&Wcc).unwrap();
        assert_eq!(after.values, compacted.values, "compaction changed results");
        assert!(
            engine.cache().stats.invalidated.load(Ordering::Relaxed) > 0,
            "compacted shards must invalidate their cache slots"
        );
        // an engine pinned to the base epoch still reproduces the original
        let pinned = VswEngine::open(
            dir,
            EngineConfig { epoch: Some(0), threads: 2, selective: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(pinned.run(&Wcc).unwrap().values, before.values);
    }

    #[test]
    fn warm_restart_matches_cold_on_monotone_apps() {
        use crate::graph::mutation::{self, Mutation};
        let n = 256;
        let edges = generator::erdos_renyi(n, 1200, 3);
        let dir = build_dataset("warm", &edges, n, 200);
        let engine = VswEngine::open(dir.clone(), EngineConfig::default()).unwrap();
        let app = Sssp { source: 0 };
        let fix0 = engine.run(&app).unwrap();

        // insert-only batch; seed = sources of the inserted edges
        let batch = vec![
            Mutation::Insert { src: 7, dst: 200, weight: 1.0 },
            Mutation::Insert { src: 200, dst: 13, weight: 1.0 },
            Mutation::Insert { src: 1, dst: 255, weight: 1.0 },
        ];
        mutation::ingest(&dir, &batch, 0.01).unwrap();
        let engine = VswEngine::open(dir.clone(), EngineConfig::default()).unwrap();
        let cold = engine.run(&app).unwrap();
        let property = crate::storage::property::Property::load(&dir.property_path()).unwrap();
        let manifest =
            crate::runtime::EpochManifest::load_or_bootstrap(&dir, &property).unwrap();
        let plan = mutation::incremental_plan(&dir, &manifest, 0, 1).unwrap().unwrap();
        assert!(plan.reset.is_empty(), "insert-only history plans no resets");
        assert_eq!(plan.seed, vec![1, 7, 200]);
        let warm = engine
            .run_seeded(&app, Some(WarmStart { values: fix0.values.clone(), active: plan.seed }))
            .unwrap();
        assert_eq!(warm.values, cold.values, "warm restart missed the cold fixpoint");
        assert!(
            warm.stats.num_iters() <= cold.stats.num_iters(),
            "warm restart should not iterate more than cold"
        );
        // an empty seed is already converged
        let noop = engine
            .run_seeded(&app, Some(WarmStart { values: cold.values.clone(), active: vec![] }))
            .unwrap();
        assert_eq!(noop.values, cold.values);
        assert_eq!(noop.stats.num_iters(), 0);
    }

    #[test]
    fn iter_stats_report_io_compute_split() {
        let edges = generator::erdos_renyi(256, 4000, 6);
        let dir = build_dataset("split", &edges, 256, 256);
        let engine = VswEngine::open(
            dir,
            EngineConfig { max_iters: 3, cache_budget: 0, selective: false, ..Default::default() },
        )
        .unwrap();
        let result = engine.run(&PageRank::default()).unwrap();
        for it in &result.stats.iters {
            assert!(it.compute > std::time::Duration::ZERO, "iter {} no compute", it.iter);
        }
        // cache disabled ⇒ shards are acquired from disk each iteration, so
        // some acquisition time must be visible somewhere in the run
        assert!(result.stats.total_io_wait() > std::time::Duration::ZERO);
        let f = result.stats.io_wait_fraction();
        assert!((0.0..=1.0).contains(&f), "fraction {f}");
    }

    #[test]
    fn direct_io_reader_is_bit_identical_and_counted() {
        let edges = generator::rmat(8, 3000, generator::RmatParams::default(), 19);
        let n = 256;
        let dir = build_dataset("directio", &edges, n, 200);
        let run = |direct_io: bool, simd: bool| {
            let engine = VswEngine::open(
                dir.clone(),
                EngineConfig {
                    cache_budget: 0, // every iteration re-reads from disk
                    selective: false,
                    max_iters: 4,
                    threads: 3,
                    direct_io,
                    simd,
                    ..Default::default()
                },
            )
            .unwrap();
            let result = engine.run(&PageRank::default()).unwrap();
            let counts = engine.direct_reader().map(|d| d.counts());
            (result.values, counts)
        };
        let (base, no_reader) = run(false, true);
        assert!(no_reader.is_none(), "reader must be absent when direct_io is off");
        for simd in [false, true] {
            let (vals, counts) = run(true, simd);
            let (d, f) = counts.expect("direct_io on must expose the reader");
            assert!(d + f > 0, "no reads went through the direct reader");
            for (i, (a, b)) in vals.iter().zip(&base).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "v{i} differs (simd={simd})");
            }
        }
    }
}
