//! Per-iteration and per-run statistics — the raw material for every figure
//! in the paper's evaluation (execution time per iteration, activation
//! ratio, I/O volume, memory, cache behaviour).

use std::time::Duration;

use crate::graph::AnyValues;
use crate::storage::io::IoSnapshot;

/// One iteration of Algorithm 1.
#[derive(Debug, Clone)]
pub struct IterStats {
    pub iter: usize,
    pub wall: Duration,
    pub shards_processed: usize,
    pub shards_skipped: usize,
    pub active_vertices: u64,
    /// |active| / |V| at the *end* of this iteration.
    pub active_ratio: f64,
    /// I/O delta over this iteration.
    pub io: IoSnapshot,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// PJRT kernel invocations (xla backend only).
    pub kernel_calls: u64,
    /// Was selective scheduling consulted this iteration?
    pub selective_enabled: bool,
    /// Time compute workers spent *acquiring* shards, summed across
    /// workers: cache probe + disk read + decompress on the synchronous
    /// path (`prefetch_depth = 0`), or waiting on the prefetch pipeline's
    /// completion channel when it runs.  With prefetching, disk time the
    /// pipeline hides behind compute does **not** appear here — shrinking
    /// `io_wait` at equal `compute` is exactly the overlap the journal
    /// version's loading figures measure.
    pub io_wait: Duration,
    /// Time compute workers spent in the vertex-update kernels plus the
    /// active-set scan, summed across workers (can exceed `wall` when
    /// several workers compute in parallel).
    pub compute: Duration,
    /// Read-ahead window this iteration ran with: the fixed
    /// `prefetch_depth` normally, the governor's planned window under
    /// `--adaptive`, 0 on the synchronous path.
    pub prefetch_depth: usize,
    /// Nanoseconds spent turning cached/compressed shard bytes into a
    /// walkable form this iteration: payload decompression into worker
    /// scratch, delta-varint chunk planning, and in-place layout
    /// validation.  On the pipelined path this work runs on the I/O pool,
    /// so it is *not* a subset of `compute` — it is the decode half of the
    /// fig7 compressed-domain ablation.  The fused varint decode inside a
    /// delta-varint gather is deliberately not separable (that fusion is
    /// the optimization) and lands in `compute`.
    pub decode_ns: u64,
}

/// Whole-run statistics.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub iters: Vec<IterStats>,
    pub total_wall: Duration,
    pub load_wall: Duration,
    /// Estimated resident memory high-water (bytes) — Fig 11's metric.
    pub memory_bytes: u64,
    pub edges_processed: u64,
}

impl RunStats {
    pub fn num_iters(&self) -> usize {
        self.iters.len()
    }

    /// Edges/second over the whole run (paper Table I's unit).
    pub fn edges_per_sec(&self) -> f64 {
        let s = self.total_wall.as_secs_f64();
        if s <= 0.0 {
            0.0
        } else {
            self.edges_processed as f64 / s
        }
    }

    pub fn total_bytes_read(&self) -> u64 {
        self.iters.iter().map(|i| i.io.bytes_read).sum()
    }

    pub fn total_bytes_written(&self) -> u64 {
        self.iters.iter().map(|i| i.io.bytes_written).sum()
    }

    /// Total worker time spent acquiring shards (see [`IterStats::io_wait`]).
    pub fn total_io_wait(&self) -> Duration {
        self.iters.iter().map(|i| i.io_wait).sum()
    }

    /// Total worker time spent computing (see [`IterStats::compute`]).
    pub fn total_compute(&self) -> Duration {
        self.iters.iter().map(|i| i.compute).sum()
    }

    /// Total shard-decode time (see [`IterStats::decode_ns`]) — the
    /// decode half of the fig7 compressed-domain split.
    pub fn total_decode_ns(&self) -> u64 {
        self.iters.iter().map(|i| i.decode_ns).sum()
    }

    /// Fraction of worker time spent acquiring shards rather than
    /// computing — the headline number for the I/O-overlap figures
    /// (0.0 = fully compute-bound, 1.0 = fully I/O-bound).
    pub fn io_wait_fraction(&self) -> f64 {
        let io = self.total_io_wait().as_secs_f64();
        let total = io + self.total_compute().as_secs_f64();
        if total <= 0.0 {
            0.0
        } else {
            io / total
        }
    }

    /// Whole-run cache hit ratio (hits / probes), 0.0 when no probes were
    /// made — one of the three numbers the CI bench gate records.
    pub fn cache_hit_ratio(&self) -> f64 {
        let hits: u64 = self.iters.iter().map(|i| i.cache_hits).sum();
        let misses: u64 = self.iters.iter().map(|i| i.cache_misses).sum();
        if hits + misses == 0 {
            0.0
        } else {
            hits as f64 / (hits + misses) as f64
        }
    }

    /// Read-ahead window of the last iteration — where the adaptive
    /// governor's feedback loop settled.
    pub fn final_prefetch_depth(&self) -> usize {
        self.iters.last().map(|i| i.prefetch_depth).unwrap_or(0)
    }

    /// Largest read-ahead window any iteration ran with (the memory
    /// high-water contribution Fig 11 must account).
    pub fn max_prefetch_depth(&self) -> usize {
        self.iters.iter().map(|i| i.prefetch_depth).max().unwrap_or(0)
    }
}

/// Final values + statistics, typed by the program's value lane
/// (defaulting to the classic `f32` so pre-lane code reads unchanged).
#[derive(Debug, Clone)]
pub struct RunResult<V = f32> {
    pub values: Vec<V>,
    pub stats: RunStats,
}

/// Lane-erased run result — what [`crate::engine::VswEngine::run_any`]
/// returns for an [`crate::apps::AnyProgram`].
#[derive(Debug, Clone)]
pub struct AnyRunResult {
    pub values: AnyValues,
    pub stats: RunStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edges_per_sec_math() {
        let stats = RunStats {
            total_wall: Duration::from_secs(2),
            edges_processed: 4_000_000,
            ..Default::default()
        };
        assert!((stats.edges_per_sec() - 2_000_000.0).abs() < 1.0);
    }

    #[test]
    fn io_totals_sum_over_iters() {
        let mk = |br: u64| IterStats {
            iter: 0,
            wall: Duration::ZERO,
            shards_processed: 0,
            shards_skipped: 0,
            active_vertices: 0,
            active_ratio: 0.0,
            io: IoSnapshot { bytes_read: br, ..Default::default() },
            cache_hits: 0,
            cache_misses: 0,
            kernel_calls: 0,
            selective_enabled: false,
            io_wait: Duration::ZERO,
            compute: Duration::ZERO,
            prefetch_depth: 0,
            decode_ns: 0,
        };
        let stats = RunStats { iters: vec![mk(10), mk(32)], ..Default::default() };
        assert_eq!(stats.total_bytes_read(), 42);
    }

    #[test]
    fn io_compute_split_sums_and_fraction() {
        let mk = |io_ms: u64, comp_ms: u64| IterStats {
            iter: 0,
            wall: Duration::ZERO,
            shards_processed: 0,
            shards_skipped: 0,
            active_vertices: 0,
            active_ratio: 0.0,
            io: IoSnapshot::default(),
            cache_hits: 0,
            cache_misses: 0,
            kernel_calls: 0,
            selective_enabled: false,
            io_wait: Duration::from_millis(io_ms),
            compute: Duration::from_millis(comp_ms),
            prefetch_depth: 0,
            decode_ns: io_ms * 1000,
        };
        let stats = RunStats { iters: vec![mk(10, 30), mk(20, 60)], ..Default::default() };
        assert_eq!(stats.total_io_wait(), Duration::from_millis(30));
        assert_eq!(stats.total_compute(), Duration::from_millis(90));
        assert_eq!(stats.total_decode_ns(), 30_000);
        assert!((stats.io_wait_fraction() - 0.25).abs() < 1e-9);
        assert_eq!(RunStats::default().io_wait_fraction(), 0.0);
    }

    #[test]
    fn cache_hit_ratio_and_depth_trajectory() {
        let mk = |hits: u64, misses: u64, depth: usize| IterStats {
            iter: 0,
            wall: Duration::ZERO,
            shards_processed: 0,
            shards_skipped: 0,
            active_vertices: 0,
            active_ratio: 0.0,
            io: IoSnapshot::default(),
            cache_hits: hits,
            cache_misses: misses,
            kernel_calls: 0,
            selective_enabled: false,
            io_wait: Duration::ZERO,
            compute: Duration::ZERO,
            prefetch_depth: depth,
            decode_ns: 0,
        };
        let stats = RunStats {
            iters: vec![mk(3, 1, 2), mk(5, 3, 4), mk(8, 0, 3)],
            ..Default::default()
        };
        assert!((stats.cache_hit_ratio() - 0.8).abs() < 1e-9);
        assert_eq!(stats.final_prefetch_depth(), 3);
        assert_eq!(stats.max_prefetch_depth(), 4);
        assert_eq!(RunStats::default().cache_hit_ratio(), 0.0);
        assert_eq!(RunStats::default().final_prefetch_depth(), 0);
    }
}
