//! The partition manifest: which worker owns which contiguous shard run.
//!
//! Shards are already the engine's unit of destination-interval ownership,
//! so a partition is just a split of `[0, num_shards)` into contiguous,
//! non-empty, in-order parts — one per worker.  Contiguity keeps each
//! worker's owned vertex ranges contiguous too (shard intervals tile the
//! vertex universe in order), which is what makes the final value stitch
//! a plain concatenation.
//!
//! The manifest survives vertex-universe growth: [`PartitionManifest::extend`]
//! folds shards appended by a later epoch into the tail part, so a saved
//! partitioning stays valid as the dataset grows.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// A split of `[0, num_shards)` into one contiguous shard run per worker.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionManifest {
    /// `parts[i] = (lo, hi)`: worker `i` owns shards `lo..hi`.  In order,
    /// non-empty, gap-free, starting at 0.
    parts: Vec<(usize, usize)>,
}

impl PartitionManifest {
    /// Even split: every part gets `num_shards / workers` shards, the
    /// first `num_shards % workers` parts one extra.
    pub fn balanced(num_shards: usize, workers: usize) -> Result<Self> {
        anyhow::ensure!(workers > 0, "a partition needs at least one worker");
        anyhow::ensure!(
            workers <= num_shards,
            "{workers} workers over {num_shards} shards leaves someone idle — \
             use at most one worker per shard"
        );
        let (base, extra) = (num_shards / workers, num_shards % workers);
        let mut parts = Vec::with_capacity(workers);
        let mut lo = 0;
        for i in 0..workers {
            let hi = lo + base + usize::from(i < extra);
            parts.push((lo, hi));
            lo = hi;
        }
        Ok(Self { parts })
    }

    /// Uneven split from explicit interior boundaries (`--split`): e.g.
    /// boundaries `[2, 5]` over 8 shards gives parts `0..2`, `2..5`,
    /// `5..8`.  Boundaries must be strictly increasing inside
    /// `(0, num_shards)`.
    pub fn from_boundaries(num_shards: usize, boundaries: &[usize]) -> Result<Self> {
        anyhow::ensure!(num_shards > 0, "cannot partition an empty dataset");
        let mut parts = Vec::with_capacity(boundaries.len() + 1);
        let mut lo = 0;
        for &b in boundaries {
            anyhow::ensure!(
                b > lo && b < num_shards,
                "split boundary {b} out of order (previous {lo}, dataset has {num_shards} shards)"
            );
            parts.push((lo, b));
            lo = b;
        }
        parts.push((lo, num_shards));
        Ok(Self { parts })
    }

    /// Parse a `--split` value: comma-separated interior shard boundaries.
    pub fn parse_split(num_shards: usize, spec: &str) -> Result<Self> {
        let boundaries = spec
            .split(',')
            .map(|t| t.trim().parse::<usize>().with_context(|| format!("bad --split token {t:?}")))
            .collect::<Result<Vec<_>>>()?;
        Self::from_boundaries(num_shards, &boundaries)
    }

    /// Grow the manifest to a dataset that gained shards (vertex-universe
    /// growth appends intervals, it never reshapes existing ones): the new
    /// shards join the tail part.  Shrinking is rejected — shards never
    /// disappear.
    pub fn extend(&mut self, new_num_shards: usize) -> Result<()> {
        let cur = self.num_shards();
        anyhow::ensure!(
            new_num_shards >= cur,
            "dataset shrank from {cur} to {new_num_shards} shards — not a growth epoch"
        );
        self.parts.last_mut().expect("manifest is never empty").1 = new_num_shards;
        Ok(())
    }

    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    pub fn num_shards(&self) -> usize {
        self.parts.last().expect("manifest is never empty").1
    }

    /// Worker `i`'s owned shard run.
    pub fn part(&self, i: usize) -> (usize, usize) {
        self.parts[i]
    }

    /// The wire form of worker `i`'s ownership for `part-init`: `"lo:hi"`.
    pub fn part_spec(&self, i: usize) -> String {
        let (lo, hi) = self.parts[i];
        format!("{lo}:{hi}")
    }

    /// Which part owns `shard`.
    pub fn owner_of(&self, shard: usize) -> Option<usize> {
        self.parts.iter().position(|&(lo, hi)| (lo..hi).contains(&shard))
    }

    pub fn to_json(&self) -> String {
        Json::Arr(
            self.parts
                .iter()
                .map(|&(lo, hi)| Json::Arr(vec![Json::Int(lo as i64), Json::Int(hi as i64)]))
                .collect(),
        )
        .to_string()
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("partition manifest")?;
        let arr = j.as_arr().context("partition manifest must be an array of [lo, hi] pairs")?;
        let mut parts = Vec::with_capacity(arr.len());
        for p in arr {
            let pair = p.as_arr().context("partition part must be [lo, hi]")?;
            let [lo, hi] = pair else { bail!("partition part must be [lo, hi]") };
            let (lo, hi) = (
                lo.as_i64().context("part lo")? as usize,
                hi.as_i64().context("part hi")? as usize,
            );
            parts.push((lo, hi));
        }
        let m = Self { parts };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.parts.is_empty(), "partition manifest has no parts");
        let mut expect = 0;
        for &(lo, hi) in &self.parts {
            anyhow::ensure!(
                lo == expect && hi > lo,
                "partition parts must be contiguous, in-order and non-empty \
                 (got [{lo}, {hi}) where [{expect}, ..) was expected)"
            );
            expect = hi;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_splits_cover_everything_in_order() {
        let m = PartitionManifest::balanced(10, 4).unwrap();
        assert_eq!(
            (0..4).map(|i| m.part(i)).collect::<Vec<_>>(),
            vec![(0, 3), (3, 6), (6, 8), (8, 10)]
        );
        assert_eq!(m.num_shards(), 10);
        assert_eq!(m.owner_of(0), Some(0));
        assert_eq!(m.owner_of(7), Some(2));
        assert_eq!(m.owner_of(9), Some(3));
        assert_eq!(m.owner_of(10), None);
        assert_eq!(m.part_spec(1), "3:6");

        // one worker per shard is the densest legal split
        let tight = PartitionManifest::balanced(3, 3).unwrap();
        assert_eq!(tight.part(2), (2, 3));
        assert!(PartitionManifest::balanced(3, 4).is_err());
        assert!(PartitionManifest::balanced(3, 0).is_err());
    }

    #[test]
    fn uneven_boundaries_parse_and_validate() {
        let m = PartitionManifest::parse_split(8, "2,5").unwrap();
        assert_eq!((m.part(0), m.part(1), m.part(2)), ((0, 2), (2, 5), (5, 8)));
        assert!(PartitionManifest::parse_split(8, "5,2").is_err());
        assert!(PartitionManifest::parse_split(8, "0,5").is_err());
        assert!(PartitionManifest::parse_split(8, "2,8").is_err());
        assert!(PartitionManifest::parse_split(8, "2,x").is_err());
    }

    #[test]
    fn extend_folds_new_shards_into_the_tail_part() {
        let mut m = PartitionManifest::balanced(6, 3).unwrap();
        m.extend(9).unwrap();
        assert_eq!(m.part(2), (4, 9));
        assert_eq!(m.num_shards(), 9);
        assert!(m.extend(8).is_err(), "shrinking must be rejected");
        // a no-growth extend is a no-op
        m.extend(9).unwrap();
        assert_eq!(m.num_shards(), 9);
    }

    #[test]
    fn json_roundtrip_and_rejection() {
        let m = PartitionManifest::balanced(10, 3).unwrap();
        let back = PartitionManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert!(PartitionManifest::from_json("[]").is_err());
        assert!(PartitionManifest::from_json("[[0,2],[3,4]]").is_err(), "gap");
        assert!(PartitionManifest::from_json("[[0,2],[2,2]]").is_err(), "empty part");
        assert!(PartitionManifest::from_json("[[1,2]]").is_err(), "must start at 0");
        assert!(PartitionManifest::from_json("{\"a\":1}").is_err());
    }
}
