//! The partition worker: one process (or thread), one contiguous shard
//! run, the full partition-protocol surface over any byte stream.
//!
//! A worker holds its own [`VswEngine`] — shards, Bloom filters, cache
//! budget — pinned to the epoch snapshot taken at open.  Its value state
//! is two full-length arrays: `cur` is globally consistent at every
//! barrier (own intervals from its own folds, remote intervals from the
//! delta lines the coordinator relays), `next` is the fold target for the
//! owned intervals only.  Each `part-step` folds the owned shards
//! *sequentially on the connection thread* through the single-process
//! engine's own [`fold_chunk`](crate::engine::vsw) path — parallelism in
//! a partitioned run is process-level by design, which is exactly what
//! makes the N-worker wall clock scale.

use std::io::{BufReader, Read, Write};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::apps::{self, AnyProgram, ProgramContext, VertexProgram, VertexValue};
use crate::bloom::{digest, Digest};
use crate::engine::partition::{decode_delta, render_value, step_shards, StepOutcome};
use crate::engine::{EngineConfig, EpochState, VswEngine};
use crate::graph::VertexId;
use crate::server::{part, Request, Response};
use crate::storage::DatasetDir;

/// Per-lane run state; the worker-side mirror of
/// [`crate::apps::AnyProgram`]'s lane erasure.
enum LaneState {
    F32(TypedState<f32>),
    F64(TypedState<f64>),
    U32(TypedState<u32>),
    U64(TypedState<u64>),
}

/// Run one expression against whichever lane is live.
macro_rules! with_lane {
    ($state:expr, $ts:ident => $body:expr) => {
        match $state {
            LaneState::F32($ts) => $body,
            LaneState::F64($ts) => $body,
            LaneState::U32($ts) => $body,
            LaneState::U64($ts) => $body,
        }
    };
}

struct TypedState<V: VertexValue> {
    app: Box<dyn VertexProgram<V>>,
    /// Globally consistent at every barrier.
    cur: Vec<V>,
    /// Fold target; only owned intervals are ever written.
    next: Vec<V>,
    /// The *global* frontier entering the next step: own actives from the
    /// last fold plus remote flag-1 vertices from the barrier payload.
    frontier: Vec<VertexId>,
}

impl<V: VertexValue> TypedState<V> {
    /// `init` and `initially_active` are pure functions of the vertex id,
    /// so every worker computes the identical full-length iteration-0
    /// state locally — the first barrier needs no value exchange.
    fn init(app: Box<dyn VertexProgram<V>>, n: usize) -> Self {
        let ctx = ProgramContext { num_vertices: n as u64 };
        let cur: Vec<V> = (0..n).map(|v| app.init(v as VertexId, &ctx)).collect();
        let next = cur.clone();
        let frontier = (0..n as VertexId).filter(|&v| app.initially_active(v, &ctx)).collect();
        Self { app, cur, next, frontier }
    }

    fn step(
        &mut self,
        engine: &VswEngine,
        st: &EpochState,
        shards: &[usize],
        global_active: u64,
        payload: &[String],
    ) -> Result<StepOutcome> {
        let n = self.cur.len();
        // barrier sync: other workers' bit-changed values land in `cur`,
        // their flag-1 vertices join the frontier — after this, `cur` and
        // `frontier` equal the single-process engine's `src` and `active`
        for line in payload {
            let (v, val, active) = decode_delta::<V>(line)?;
            anyhow::ensure!((v as usize) < n, "delta line for vertex {v} outside the dataset");
            self.cur[v as usize] = val;
            if active {
                self.frontier.push(v);
            }
        }
        // the selective decision is a pure function of the merged global
        // count the coordinator broadcast — every worker (and the
        // single-process engine) resolves it identically
        let cfg = engine.config();
        let ratio = global_active as f64 / n.max(1) as f64;
        let selective_now =
            cfg.selective && ratio > 0.0 && ratio < cfg.selective_threshold;
        let mut digests: Vec<Digest> = Vec::new();
        if selective_now {
            self.frontier.sort_unstable();
            digests.extend(self.frontier.iter().map(|&v| digest(v as u64)));
        }
        let out = step_shards(
            engine,
            st,
            self.app.as_ref(),
            shards,
            selective_now,
            &digests,
            &self.cur,
            &mut self.next,
        )?;
        // commit own intervals; remote intervals stay at the previous
        // iteration until the next barrier payload re-syncs them
        for &shard in shards {
            let (lo, hi) = st.property.interval(shard);
            let (lo, hi) = (lo as usize, hi as usize);
            self.cur[lo..hi].copy_from_slice(&self.next[lo..hi]);
        }
        self.frontier.clear();
        self.frontier.extend_from_slice(&out.active);
        Ok(out)
    }

    /// `"{v} {bits}"` per owned vertex, ascending — the coordinator
    /// stitches these into a full `--dump-values`-identical rendering.
    fn values_lines(&self, st: &EpochState, shards: &[usize]) -> Vec<String> {
        let mut lines = Vec::new();
        for &shard in shards {
            let (lo, hi) = st.property.interval(shard);
            for v in lo..hi {
                lines.push(format!("{v} {}", render_value(self.cur[v as usize])));
            }
        }
        lines
    }
}

/// One partition worker: engine + pinned snapshot + lane-typed run state.
pub struct Worker {
    engine: VswEngine,
    st: Arc<EpochState>,
    shards: Vec<usize>,
    state: Option<LaneState>,
    /// Fault injection (`GRAPHMP_PART_CRASH_ITER`): drop the connection
    /// without responding on the `part-step` carrying this iteration
    /// number, so coordinator crash handling can be exercised end to end.
    pub crash_iter: Option<u64>,
}

impl Worker {
    pub fn open(dir: DatasetDir, cfg: EngineConfig) -> Result<Worker> {
        let engine = VswEngine::open(dir, cfg)?;
        let st = engine.snapshot();
        Ok(Worker { engine, st, shards: Vec::new(), state: None, crash_iter: None })
    }

    pub fn epoch(&self) -> u64 {
        self.st.epoch
    }

    /// Serve the coordinator's connection until `part-shutdown`, EOF, or
    /// an injected crash.  One request, one response, in order — the
    /// coordinator's post-all-then-recv-all barrier depends on it.
    pub fn serve_connection<S: Read + Write>(&mut self, stream: S) -> Result<()> {
        let mut reader = BufReader::new(stream);
        loop {
            let Some(req) = Request::read_from(&mut reader)? else {
                return Ok(()); // coordinator hung up
            };
            if req.cmd == part::STEP {
                if let (Some(c), Ok(Some(i))) = (self.crash_iter, req.get_u64("iter")) {
                    if i == c {
                        // die mid-iteration with the response unsent: the
                        // coordinator must surface this, not hang
                        bail!("injected worker crash at iteration {i}");
                    }
                }
            }
            let shutdown = req.cmd == part::SHUTDOWN;
            let resp = self.handle(&req);
            let out = resp.render();
            let s = reader.get_mut();
            s.write_all(out.as_bytes())?;
            s.flush()?;
            if shutdown {
                return Ok(());
            }
        }
    }

    /// One request, one response; errors become `err` responses (the
    /// connection survives a rejected request).
    pub fn handle(&mut self, req: &Request) -> Response {
        match self.dispatch(req) {
            Ok(resp) => resp,
            Err(e) => Response::err(format!("{e:#}")),
        }
    }

    fn dispatch(&mut self, req: &Request) -> Result<Response> {
        match req.cmd.as_str() {
            part::INIT => self.cmd_init(req),
            part::STEP => self.cmd_step(req),
            part::VALUES => self.cmd_values(),
            part::SHUTDOWN => Ok(Response::ok().with("bye", 1)),
            other => bail!("unknown partition verb {other:?}"),
        }
    }

    fn cmd_init(&mut self, req: &Request) -> Result<Response> {
        let any = apps::by_name(req.req("app")?)?;
        let spec = req.req("shards")?;
        let p = self.st.property.num_shards();
        let mut shards: Vec<usize> = Vec::new();
        for range in spec.split(',') {
            let (lo, hi) = range
                .split_once(':')
                .with_context(|| format!("bad shard range {range:?} (want lo:hi)"))?;
            let lo: usize = lo.parse().with_context(|| format!("bad shard range {range:?}"))?;
            let hi: usize = hi.parse().with_context(|| format!("bad shard range {range:?}"))?;
            anyhow::ensure!(
                lo < hi && hi <= p,
                "shard range {range:?} out of bounds (dataset has {p} shards)"
            );
            anyhow::ensure!(
                shards.last().is_none_or(|&s| s < lo),
                "shard ranges must be ascending and disjoint"
            );
            shards.extend(lo..hi);
        }
        let n = self.st.property.info.num_vertices as usize;
        let lane = any.lane();
        let state = match any {
            AnyProgram::F32(app) => LaneState::F32(TypedState::init(app, n)),
            AnyProgram::F64(app) => LaneState::F64(TypedState::init(app, n)),
            AnyProgram::U32(app) => LaneState::U32(TypedState::init(app, n)),
            AnyProgram::U64(app) => LaneState::U64(TypedState::init(app, n)),
        };
        let active = with_lane!(&state, ts => ts.frontier.len());
        self.shards = shards;
        self.state = Some(state);
        Ok(Response::ok()
            .with("epoch", self.st.epoch)
            .with("vertices", n)
            .with("lane", lane.name())
            .with("active", active))
    }

    fn cmd_step(&mut self, req: &Request) -> Result<Response> {
        req.req_u64("iter")?;
        let global_active = req.req_u64("active")?;
        let state = self.state.as_mut().context("part-step before part-init")?;
        let out = with_lane!(state, ts => ts.step(
            &self.engine,
            &self.st,
            &self.shards,
            global_active,
            &req.payload,
        ))?;
        let (active, processed, skipped, edges) =
            (out.active.len(), out.shards_processed, out.shards_skipped, out.edges);
        Ok(Response::ok()
            .with("active", active)
            .with("processed", processed)
            .with("skipped", skipped)
            .with("edges", edges)
            .with_payload(out.lines))
    }

    fn cmd_values(&self) -> Result<Response> {
        let state = self.state.as_ref().context("part-values before part-init")?;
        let lines = with_lane!(state, ts => ts.values_lines(&self.st, &self.shards));
        Ok(Response::ok().with("vertices", lines.len()).with_payload(lines))
    }
}

/// In-process worker: a thread serving one end of a socketpair — the
/// test/bench stand-in for a spawned `partworker` process.  Same protocol
/// bytes, same barrier behavior, no exec.  The engine opens inside the
/// thread; an open failure surfaces at the coordinator's first receive as
/// a closed connection, and precisely in the returned join handle.
#[cfg(unix)]
pub fn spawn_local(
    dir: DatasetDir,
    cfg: EngineConfig,
    crash_iter: Option<u64>,
) -> Result<(std::os::unix::net::UnixStream, std::thread::JoinHandle<Result<()>>)> {
    let (ours, theirs) = std::os::unix::net::UnixStream::pair()?;
    let handle = std::thread::spawn(move || {
        let mut w = Worker::open(dir, cfg)?;
        w.crash_iter = crash_iter;
        w.serve_connection(theirs)
    });
    Ok((ours, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::sharding::{preprocess, PreprocessConfig};

    fn build_dataset(tag: &str) -> DatasetDir {
        let dir = DatasetDir::new(
            std::env::temp_dir().join(format!("gmp_partworker_{tag}_{}", std::process::id())),
        );
        let _ = std::fs::remove_dir_all(&dir.root);
        let edges = generator::erdos_renyi(96, 700, 11);
        let cfg = PreprocessConfig { max_edges_per_shard: 128, bloom_fpr: 0.01 };
        preprocess(tag, &edges, 96, &dir, &cfg).unwrap();
        dir
    }

    #[test]
    fn worker_rejects_protocol_misuse_without_dying() {
        let dir = build_dataset("misuse");
        let mut w = Worker::open(dir.clone(), EngineConfig::default()).unwrap();
        let p = {
            let prop =
                crate::storage::property::Property::load(&dir.property_path()).unwrap();
            prop.num_shards()
        };
        assert!(p >= 2, "test graph must span several shards, got {p}");

        // step/values before init
        let step = Request::new(part::STEP).arg("iter", "0").arg("active", "5");
        assert!(w.handle(&step).error.is_some());
        assert!(w.handle(&Request::new(part::VALUES)).error.is_some());

        // malformed shard specs
        for bad in ["", "3", "2:1", "0:999", "1:2,0:1", "x:2"] {
            let r = w.handle(&Request::new(part::INIT).arg("app", "pagerank").arg("shards", bad));
            assert!(r.error.is_some(), "shards={bad:?} must be rejected");
        }
        let r = w.handle(&Request::new(part::INIT).arg("app", "nosuch").arg("shards", "0:1"));
        assert!(r.error.is_some());

        // a good init answers the full projection
        let spec = format!("0:{p}");
        let ok = w.handle(&Request::new(part::INIT).arg("app", "pagerank").arg("shards", &spec));
        assert!(ok.is_ok(), "{:?}", ok.error);
        assert_eq!(ok.get("vertices"), Some("96"));
        assert_eq!(ok.get("lane"), Some("f32"));
        assert_eq!(ok.get("active"), Some("96"), "pagerank starts fully active");

        // garbage barrier payload is rejected, not applied
        let r = w.handle(
            &Request::new(part::STEP)
                .arg("iter", "0")
                .arg("active", "96")
                .with_payload(vec!["not a delta line".into()]),
        );
        assert!(r.error.is_some());

        // unknown verbs err
        assert!(w.handle(&Request::new("frobnicate")).error.is_some());
        let _ = std::fs::remove_dir_all(&dir.root);
    }

    #[test]
    fn single_worker_owning_everything_matches_run() {
        let dir = build_dataset("solo");
        let cfg = EngineConfig { threads: 1, ..Default::default() };
        let engine = VswEngine::open(dir.clone(), cfg.clone()).unwrap();
        let app = apps::by_name("pagerank").unwrap();
        let reference = engine.run_any(&app).unwrap();

        let mut w = Worker::open(dir.clone(), cfg).unwrap();
        let p = w.st.property.num_shards();
        let spec = format!("0:{p}");
        let init = w.handle(&Request::new(part::INIT).arg("app", "pagerank").arg("shards", &spec));
        assert!(init.is_ok(), "{:?}", init.error);
        let mut active: u64 = init.get("active").unwrap().parse().unwrap();
        for iter in 0..app.default_max_iters() {
            if active == 0 {
                break;
            }
            let resp = w.handle(
                &Request::new(part::STEP)
                    .arg("iter", &iter.to_string())
                    .arg("active", &active.to_string()),
            );
            assert!(resp.is_ok(), "{:?}", resp.error);
            active = resp.get("active").unwrap().parse().unwrap();
        }
        let vals = w.handle(&Request::new(part::VALUES));
        assert!(vals.is_ok(), "{:?}", vals.error);
        assert_eq!(vals.payload.len(), 96);
        for (v, line) in vals.payload.iter().enumerate() {
            let (id, bits) = line.split_once(' ').unwrap();
            assert_eq!(id.parse::<usize>().unwrap(), v);
            assert_eq!(
                bits,
                reference.values.render_bits(v).unwrap(),
                "vertex {v} diverged from the single-process run"
            );
        }
        let _ = std::fs::remove_dir_all(&dir.root);
    }
}
