//! The partition coordinator: drives N workers through iteration
//! barriers over the line protocol and merges their projections.
//!
//! The barrier is post-all-then-receive-all: every worker gets its
//! `part-step` (with the *other* workers' delta lines from the previous
//! iteration as payload) before the coordinator reads any response, so
//! all N folds run concurrently and the receive loop is the
//! synchronization point.  Between barriers the coordinator only merges
//! counts and re-routes delta lines — it never touches values, which is
//! why a partitioned run is bit-identical to the single-process engine:
//! the workers compute with the engine's own fold path and the
//! coordinator is pure plumbing.
//!
//! A worker that dies mid-iteration closes its socket; the next receive
//! on that link fails ("connection closed") and the run surfaces a clean
//! error naming the worker instead of hanging on a barrier that can
//! never complete.

use std::io::{BufReader, Read, Write};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::apps;
use crate::obs;
use crate::server::{part, Request, Response};

use super::manifest::PartitionManifest;

/// One worker connection, split so the barrier can post to every worker
/// before receiving from any.
pub trait WorkerLink {
    /// Write one request; do not wait for the response.
    fn post(&mut self, req: &Request) -> Result<()>;
    /// Read the next response (blocks).
    fn recv(&mut self) -> Result<Response>;
}

/// [`WorkerLink`] over any byte stream — a Unix socket to a `partworker`
/// process, or a socketpair into an in-process worker thread.
pub struct StreamLink<S: Read + Write> {
    reader: BufReader<S>,
}

impl<S: Read + Write> StreamLink<S> {
    pub fn new(stream: S) -> Self {
        Self { reader: BufReader::new(stream) }
    }
}

impl<S: Read + Write> WorkerLink for StreamLink<S> {
    fn post(&mut self, req: &Request) -> Result<()> {
        let s = self.reader.get_mut();
        s.write_all(req.render().as_bytes())?;
        s.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        Response::read_from(&mut self.reader)
    }
}

/// One iteration barrier, as the coordinator saw it.
#[derive(Debug, Clone)]
pub struct PartIterStats {
    pub iter: usize,
    /// Merged global active count *after* this iteration.
    pub active: u64,
    pub shards_processed: usize,
    pub shards_skipped: usize,
    /// Delta lines exchanged at this barrier (sum over workers).
    pub delta_lines: usize,
    pub edges: u64,
    pub wall: Duration,
}

/// What a partitioned run produced.
#[derive(Debug)]
pub struct PartRunSummary {
    pub app: String,
    pub epoch: u64,
    pub vertices: usize,
    pub lane: String,
    pub workers: usize,
    pub iters: Vec<PartIterStats>,
    pub total_wall: Duration,
    /// Final values in `--dump-values` form (one bit-line per vertex,
    /// ascending); empty unless requested.
    pub values: Vec<String>,
    /// High-water bytes held in the coordinator's stitch buffers (the
    /// per-worker delta outboxes plus the value-collection staging) —
    /// charged into partitioned memory accounting.
    pub stitch_bytes: u64,
}

pub struct Coordinator<L: WorkerLink> {
    manifest: PartitionManifest,
    links: Vec<L>,
}

impl<L: WorkerLink> Coordinator<L> {
    pub fn new(manifest: PartitionManifest, links: Vec<L>) -> Result<Self> {
        anyhow::ensure!(
            manifest.num_parts() == links.len(),
            "manifest has {} parts but {} workers are connected",
            manifest.num_parts(),
            links.len()
        );
        Ok(Self { manifest, links })
    }

    fn worker_tag(&self, i: usize) -> String {
        let (lo, hi) = self.manifest.part(i);
        format!("worker {i} (shards {lo}..{hi})")
    }

    fn post(&mut self, i: usize, req: &Request) -> Result<()> {
        let tag = self.worker_tag(i);
        self.links[i].post(req).with_context(|| tag)
    }

    /// Receive and unwrap one response; transport failures (a dead
    /// worker's closed socket) and `err` answers both surface with the
    /// worker's identity attached.
    fn recv_ok(&mut self, i: usize) -> Result<Response> {
        let tag = self.worker_tag(i);
        let resp = self.links[i].recv().with_context(|| tag.clone())?;
        match resp.error {
            Some(e) => bail!("{tag}: {e}"),
            None => Ok(resp),
        }
    }

    /// Drive `app` to convergence (or the iteration cap) across all
    /// workers.  `max_iters = 0` defers to the app's default, exactly
    /// like [`crate::engine::EngineConfig::max_iters`].
    pub fn run(
        &mut self,
        app_name: &str,
        max_iters: usize,
        collect_values: bool,
    ) -> Result<PartRunSummary> {
        let t0 = Instant::now();
        let app = apps::by_name(app_name)?;
        let max_iters = if max_iters > 0 { max_iters } else { app.default_max_iters() };
        let w = self.links.len();

        // barrier 0: bind the program and owned ranges everywhere, then
        // cross-check that every worker projects the same world
        for i in 0..w {
            let req = Request::new(part::INIT)
                .arg("app", app_name)
                .arg("shards", &self.manifest.part_spec(i));
            self.post(i, &req)?;
        }
        let (mut epoch, mut vertices, mut lane, mut global_active) =
            (0u64, 0usize, String::new(), 0u64);
        for i in 0..w {
            let resp = self.recv_ok(i)?;
            let e = resp_u64(&resp, "epoch")?;
            let n = resp_u64(&resp, "vertices")? as usize;
            let l = resp.get("lane").context("init response missing lane=")?.to_string();
            let a = resp_u64(&resp, "active")?;
            if i == 0 {
                (epoch, vertices, lane, global_active) = (e, n, l, a);
            } else {
                anyhow::ensure!(
                    (e, n, &l, a) == (epoch, vertices, &lane, global_active),
                    "{} initialized at epoch {e} / {n} vertices / {a} active, \
                     worker 0 at epoch {epoch} / {vertices} / {global_active} — \
                     did an ingest land between worker spawns?",
                    self.worker_tag(i)
                );
            }
        }

        // per-worker outbox: the delta lines each worker must apply at
        // its next barrier (everyone else's changes from the last one)
        let mut pending: Vec<Vec<String>> = vec![Vec::new(); w];
        let mut iters = Vec::new();
        let mut stitch_bytes: u64 = 0;
        obs::trace::record_run_start(app.name(), epoch);

        for iter in 0..max_iters {
            if global_active == 0 {
                break;
            }
            let t_iter = Instant::now();
            for i in 0..w {
                let req = Request::new(part::STEP)
                    .arg("iter", &iter.to_string())
                    .arg("active", &global_active.to_string())
                    .with_payload(std::mem::take(&mut pending[i]));
                self.post(i, &req)?;
            }
            let mut outs = Vec::with_capacity(w);
            for i in 0..w {
                outs.push(self.recv_ok(i)?);
            }
            // post-all → receive-all is the barrier; its latency is the
            // coordinator's foremost health signal
            obs::metrics::observe_secs(
                "graphmp_barrier_seconds",
                &[],
                t_iter.elapsed().as_secs_f64(),
            );
            let mut stats = PartIterStats {
                iter,
                active: 0,
                shards_processed: 0,
                shards_skipped: 0,
                delta_lines: 0,
                edges: 0,
                wall: Duration::ZERO,
            };
            for resp in &outs {
                stats.active += resp_u64(resp, "active")?;
                stats.shards_processed += resp_u64(resp, "processed")? as usize;
                stats.shards_skipped += resp_u64(resp, "skipped")? as usize;
                stats.edges += resp_u64(resp, "edges")?;
                stats.delta_lines += resp.payload.len();
            }
            for (i, outbox) in pending.iter_mut().enumerate() {
                for (j, resp) in outs.iter().enumerate() {
                    if j != i {
                        outbox.extend(resp.payload.iter().cloned());
                    }
                }
            }
            let outbox_bytes: u64 =
                pending.iter().flatten().map(|l| l.len() as u64 + 24).sum();
            stitch_bytes = stitch_bytes.max(outbox_bytes);
            global_active = stats.active;
            stats.wall = t_iter.elapsed();
            obs::metrics::counter_add(
                "graphmp_barrier_delta_lines_total",
                &[],
                stats.delta_lines as u64,
            );
            if obs::trace::installed() {
                obs::trace::record(obs::trace::TraceRecord::Iter {
                    epoch,
                    iter: iter as u64,
                    wall_ns: stats.wall.as_nanos() as u64,
                    io_wait_ns: 0,
                    compute_ns: 0,
                    decode_ns: 0,
                    shards_processed: stats.shards_processed as u64,
                    shards_skipped: stats.shards_skipped as u64,
                    active: stats.active,
                    read_bytes: 0,
                    cache_hits: 0,
                    cache_misses: 0,
                    window: stats.delta_lines as u64,
                });
            }
            iters.push(stats);
        }

        let values =
            if collect_values { self.collect_values(vertices)? } else { Vec::new() };
        let value_bytes: u64 =
            values.iter().map(|l| l.len() as u64 + 24 + 1).sum::<u64>();
        stitch_bytes = stitch_bytes.max(value_bytes);
        obs::metrics::gauge_set("graphmp_part_stitch_bytes", &[], stitch_bytes);
        self.shutdown();

        Ok(PartRunSummary {
            app: app.name().to_string(),
            epoch,
            vertices,
            lane,
            workers: w,
            iters,
            total_wall: t0.elapsed(),
            values,
            stitch_bytes,
        })
    }

    /// Stitch every worker's owned intervals into one full ascending
    /// rendering — byte-identical to the single-process `--dump-values`.
    fn collect_values(&mut self, n: usize) -> Result<Vec<String>> {
        let w = self.links.len();
        for i in 0..w {
            self.post(i, &Request::new(part::VALUES))?;
        }
        let mut values = vec![String::new(); n];
        let mut filled = vec![false; n];
        for i in 0..w {
            let resp = self.recv_ok(i)?;
            for line in resp.payload {
                let (v, bits) = line
                    .split_once(' ')
                    .with_context(|| format!("bad value line {line:?}"))?;
                let v: usize = v.parse().with_context(|| format!("bad value line {line:?}"))?;
                anyhow::ensure!(v < n, "value line for vertex {v} outside the dataset");
                anyhow::ensure!(!filled[v], "vertex {v} reported by two workers");
                values[v] = bits.to_string();
                filled[v] = true;
            }
        }
        let missing = filled.iter().filter(|&&f| !f).count();
        anyhow::ensure!(missing == 0, "{missing} vertices reported by no worker");
        Ok(values)
    }

    /// Best-effort clean exit: a worker that already died stays dead, the
    /// rest get to leave gracefully.
    fn shutdown(&mut self) {
        let w = self.links.len();
        for i in 0..w {
            let _ = self.links[i].post(&Request::new(part::SHUTDOWN));
        }
        for link in &mut self.links {
            let _ = link.recv();
        }
    }
}

fn resp_u64(resp: &Response, key: &str) -> Result<u64> {
    resp.get(key)
        .with_context(|| format!("worker response missing {key}="))?
        .parse::<u64>()
        .with_context(|| format!("worker response: bad {key}="))
}

/// Spawning and reaping `partworker` child processes (the `partrun` CLI
/// path).  Unix-only: worker links ride Unix-domain sockets.
#[cfg(unix)]
pub mod process {
    use super::*;
    use std::os::unix::net::UnixStream;
    use std::path::{Path, PathBuf};
    use std::process::{Child, Command, Stdio};

    /// The spawned children.  Dropping kills any still-running worker, so
    /// a coordinator error can't leak orphan processes.
    pub struct ProcessWorkers {
        children: Vec<Child>,
        sock_dir: PathBuf,
    }

    impl ProcessWorkers {
        /// Spawn one `partworker` per manifest part and connect to each.
        /// `forward` is the engine flag tail every worker receives
        /// verbatim (so workers run the exact config `partrun` was given).
        pub fn spawn(
            exe: &Path,
            data: &Path,
            manifest: &PartitionManifest,
            forward: &[String],
            timeout: Duration,
        ) -> Result<(Self, Vec<StreamLink<UnixStream>>)> {
            let sock_dir = std::env::temp_dir()
                .join(format!("gmp_part_{}_{:x}", std::process::id(), manifest.num_parts()));
            std::fs::create_dir_all(&sock_dir)?;
            let mut this = Self { children: Vec::new(), sock_dir };
            let mut socks = Vec::new();
            for i in 0..manifest.num_parts() {
                let sock = this.sock_dir.join(format!("w{i}.sock"));
                let _ = std::fs::remove_file(&sock);
                let child = Command::new(exe)
                    .arg("partworker")
                    .arg("--data")
                    .arg(data)
                    .arg("--socket")
                    .arg(&sock)
                    .arg("--worker-id")
                    .arg(i.to_string())
                    .args(forward)
                    .stdin(Stdio::null())
                    .spawn()
                    .with_context(|| format!("spawning worker {i}"))?;
                this.children.push(child);
                socks.push(sock);
            }
            let mut links = Vec::new();
            for (i, sock) in socks.iter().enumerate() {
                let deadline = Instant::now() + timeout;
                let stream = loop {
                    match UnixStream::connect(sock) {
                        Ok(s) => break s,
                        Err(e) => {
                            // a worker that died during engine load never
                            // listens — surface its exit, don't time out
                            if let Ok(Some(status)) = this.children[i].try_wait() {
                                bail!("worker {i} exited during startup ({status})");
                            }
                            if Instant::now() >= deadline {
                                return Err(anyhow::Error::from(e)).with_context(|| {
                                    format!("worker {i} never came up on {}", sock.display())
                                });
                            }
                            std::thread::sleep(Duration::from_millis(20));
                        }
                    }
                };
                links.push(StreamLink::new(stream));
            }
            Ok((this, links))
        }
    }

    impl Drop for ProcessWorkers {
        fn drop(&mut self) {
            for c in &mut self.children {
                // cleanly-exited children are no-ops; stragglers die here
                let _ = c.kill();
                let _ = c.wait();
            }
            let _ = std::fs::remove_dir_all(&self.sock_dir);
        }
    }
}
