//! Partitioned VSW execution (`graphmp partrun`): N worker processes,
//! each owning a contiguous interval range, driven through iteration
//! barriers by a coordinator over the serve line protocol.
//!
//! Division of labor:
//!
//! * [`manifest`] — which worker owns which contiguous shard run, with
//!   growth support (new intervals fold into the tail part).
//! * [`worker`] — engine + pinned snapshot + lane-typed value state;
//!   folds its owned shards through the single-process engine's own
//!   chunk path, so its bits are the engine's bits.
//! * [`coordinator`] — post-all/receive-all barriers, delta-line
//!   routing, merged-active convergence, final value stitching, and
//!   clean failure when a worker dies mid-iteration.
//!
//! The invariant the whole module is built around: partitioned runs are
//! **bit-identical** to single-process VSW runs, for every app, worker
//! count and split — see [`crate::engine::partition`] for the argument.

pub mod coordinator;
pub mod manifest;
pub mod worker;

pub use coordinator::{Coordinator, PartIterStats, PartRunSummary, StreamLink, WorkerLink};
pub use manifest::PartitionManifest;
pub use worker::Worker;
