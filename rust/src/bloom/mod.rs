//! Bloom filters for selective scheduling (paper §II-D.1).
//!
//! Each shard gets a filter over the **source** vertices of its edges.  When
//! the active-vertex ratio drops below the threshold (paper: 1/1000), the
//! engine probes each shard's filter with the active set; a shard whose
//! filter contains no active vertex is provably inactive (no false
//! negatives) and is skipped — no disk read, no compute.

use anyhow::Result;

use crate::util::bitset::BitSet;
use crate::util::hash::{bloom_basis, bloom_indexes};

/// Maximum number of probe hashes supported.
pub const MAX_K: u32 = 16;

/// A key's precomputed double-hashing basis: the filter-independent part
/// of a Bloom probe.  The engine probes every shard's filter with the same
/// active set each iteration; hashing each vertex once into a `Digest` and
/// reusing it across all `P` filters turns the screening cost from
/// `O(P × |active| × hash)` into `O(|active| × hash + P × |active| × k)`
/// integer ops — the hash is the expensive part.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Digest {
    h1: u64,
    h2: u64,
}

/// Hash a key once into its reusable probe [`Digest`].
#[inline]
pub fn digest(key: u64) -> Digest {
    let (h1, h2) = bloom_basis(key);
    Digest { h1, h2 }
}

/// A standard Bloom filter keyed by `u64` (vertex ids widen losslessly).
#[derive(Debug, Clone)]
pub struct BloomFilter {
    bits: BitSet,
    k: u32,
    items: u64,
}

impl BloomFilter {
    /// Filter with `m_bits` bits and `k` hash probes.
    pub fn new(m_bits: usize, k: u32) -> Self {
        assert!(m_bits > 0 && k > 0 && k <= MAX_K);
        Self { bits: BitSet::new(m_bits), k, items: 0 }
    }

    /// Size a filter for `n` expected items at `fpr` target false-positive
    /// rate: `m = -n ln p / (ln 2)^2`, `k = (m/n) ln 2`.
    pub fn with_capacity(n: usize, fpr: f64) -> Self {
        let n = n.max(1) as f64;
        let fpr = fpr.clamp(1e-9, 0.5);
        let m = (-(n * fpr.ln()) / (std::f64::consts::LN_2 * std::f64::consts::LN_2)).ceil();
        let k = ((m / n) * std::f64::consts::LN_2).round().clamp(1.0, MAX_K as f64);
        Self::new((m as usize).max(64), k as u32)
    }

    pub fn insert(&mut self, key: u64) {
        let mut idx = [0u64; MAX_K as usize];
        bloom_indexes(key, self.k, self.bits.len() as u64, &mut idx);
        for &i in &idx[..self.k as usize] {
            self.bits.set(i as usize);
        }
        self.items += 1;
    }

    /// Probe with a precomputed basis: derives this filter's `k` bit
    /// positions from `(h1, h2)` — identical bits to [`Self::contains`].
    #[inline]
    pub fn contains_digest(&self, d: Digest) -> bool {
        let m = self.bits.len() as u64;
        (0..self.k as u64)
            .all(|i| self.bits.get((d.h1.wrapping_add(d.h2.wrapping_mul(i)) % m) as usize))
    }

    /// May return a false positive; never a false negative.
    pub fn contains(&self, key: u64) -> bool {
        self.contains_digest(digest(key))
    }

    /// True if any key in `keys` may be present (the shard-activity probe).
    pub fn contains_any<I: IntoIterator<Item = u64>>(&self, keys: I) -> bool {
        keys.into_iter().any(|k| self.contains(k))
    }

    /// [`Self::contains_any`] over pre-hashed digests — the engine hashes
    /// each active vertex once per iteration and screens every shard's
    /// filter with the same digest array.
    pub fn contains_any_digest(&self, digests: &[Digest]) -> bool {
        digests.iter().any(|&d| self.contains_digest(d))
    }

    /// How many of `keys` may be present — the I/O governor's active-source
    /// density signal (§selective scheduling turns the same filters it
    /// skips shards with into a shard-priority estimate).  Counts false
    /// positives like any Bloom probe, but never undercounts.
    pub fn count_contained<I: IntoIterator<Item = u64>>(&self, keys: I) -> usize {
        keys.into_iter().filter(|&k| self.contains(k)).count()
    }

    /// [`Self::count_contained`] over pre-hashed digests.
    pub fn count_contained_digest(&self, digests: &[Digest]) -> usize {
        digests.iter().filter(|&&d| self.contains_digest(d)).count()
    }

    /// Empirical bits-set ratio (diagnostics / load factor).
    pub fn fill_ratio(&self) -> f64 {
        self.bits.count_ones() as f64 / self.bits.len() as f64
    }

    /// Theoretical false-positive rate at the current fill.
    pub fn est_fpr(&self) -> f64 {
        self.fill_ratio().powi(self.k as i32)
    }

    pub fn num_bits(&self) -> usize {
        self.bits.len()
    }

    pub fn num_hashes(&self) -> u32 {
        self.k
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    /// Memory footprint of the bit array in bytes.
    pub fn size_bytes(&self) -> usize {
        self.bits.words().len() * 8
    }

    // ---- serialization (bloom_XXXX.gmb payload) ----------------------------

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + self.bits.words().len() * 8);
        out.extend_from_slice(&(self.bits.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.k.to_le_bytes());
        out.extend_from_slice(&(self.items).to_le_bytes());
        for w in self.bits.words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        anyhow::ensure!(buf.len() >= 20, "bloom header truncated");
        let m = u64::from_le_bytes(buf[0..8].try_into().unwrap()) as usize;
        let k = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        let items = u64::from_le_bytes(buf[12..20].try_into().unwrap());
        anyhow::ensure!(k >= 1 && k <= MAX_K, "bloom k out of range");
        let nwords = m.div_ceil(64);
        anyhow::ensure!(buf.len() == 20 + nwords * 8, "bloom payload size mismatch");
        let words = buf[20..]
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Self { bits: BitSet::from_words(words, m), k, items })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn no_false_negatives() {
        let mut f = BloomFilter::with_capacity(10_000, 0.01);
        for key in 0..10_000u64 {
            f.insert(key * 7919);
        }
        for key in 0..10_000u64 {
            assert!(f.contains(key * 7919));
        }
    }

    #[test]
    fn fpr_near_target() {
        let mut f = BloomFilter::with_capacity(10_000, 0.01);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_u64()).collect();
        for &k in &keys {
            f.insert(k);
        }
        // probe disjoint keys
        let fp = (0..100_000)
            .filter(|_| f.contains(rng.next_u64() | (1 << 63)))
            .count();
        let rate = fp as f64 / 100_000.0;
        assert!(rate < 0.05, "fpr {rate} too high for 1% target");
    }

    #[test]
    fn with_capacity_sizing() {
        let f = BloomFilter::with_capacity(1000, 0.01);
        // ~9.6 bits/item, ~7 hashes for 1% fpr
        assert!((8000..12000).contains(&f.num_bits()), "{}", f.num_bits());
        assert!((6..=8).contains(&f.num_hashes()));
    }

    #[test]
    fn serialization_roundtrip() {
        let mut f = BloomFilter::with_capacity(500, 0.02);
        for k in 0..500u64 {
            f.insert(k * 31);
        }
        let bytes = f.to_bytes();
        let g = BloomFilter::from_bytes(&bytes).unwrap();
        assert_eq!(g.num_bits(), f.num_bits());
        assert_eq!(g.num_hashes(), f.num_hashes());
        assert_eq!(g.items(), 500);
        for k in 0..500u64 {
            assert!(g.contains(k * 31));
        }
    }

    #[test]
    fn from_bytes_rejects_corrupt() {
        let f = BloomFilter::with_capacity(100, 0.01);
        let bytes = f.to_bytes();
        assert!(BloomFilter::from_bytes(&bytes[..10]).is_err());
        let mut bad = bytes.clone();
        bad[8] = 99; // k out of range
        assert!(BloomFilter::from_bytes(&bad).is_err());
        let mut short = bytes;
        short.truncate(short.len() - 8);
        assert!(BloomFilter::from_bytes(&short).is_err());
    }

    #[test]
    fn prop_inserted_always_contained() {
        prop::check(0xB100, 30, |g| {
            let n = g.usize_in(1, 400);
            let mut f = BloomFilter::with_capacity(n, 0.01);
            let keys: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            for &k in &keys {
                f.insert(k);
            }
            for &k in &keys {
                assert!(f.contains(k), "false negative for {k}");
            }
            assert!(f.contains_any(keys.iter().copied()));
            assert_eq!(
                f.count_contained(keys.iter().copied()),
                keys.len(),
                "count_contained must never undercount inserted keys"
            );
        });
    }

    #[test]
    fn digest_probes_agree_with_key_probes() {
        // one digest per key, probed against filters of different (m, k)
        // geometries, must answer exactly like the per-key path
        let mut filters = vec![
            BloomFilter::with_capacity(100, 0.01),
            BloomFilter::with_capacity(5000, 0.001),
            BloomFilter::new(64, 1),
        ];
        let mut rng = Xoshiro256::seed_from_u64(11);
        let keys: Vec<u64> = (0..300).map(|_| rng.next_u64()).collect();
        for f in &mut filters {
            for &k in keys.iter().step_by(3) {
                f.insert(k);
            }
        }
        let digests: Vec<Digest> = keys.iter().map(|&k| digest(k)).collect();
        for f in &filters {
            for (&k, &d) in keys.iter().zip(&digests) {
                assert_eq!(f.contains(k), f.contains_digest(d));
            }
            assert_eq!(
                f.count_contained(keys.iter().copied()),
                f.count_contained_digest(&digests)
            );
            assert_eq!(
                f.contains_any(keys.iter().copied()),
                f.contains_any_digest(&digests)
            );
        }
        assert!(!filters[0].contains_any_digest(&[]));
        assert_eq!(filters[0].count_contained_digest(&[]), 0);
    }

    #[test]
    fn count_contained_measures_density() {
        let mut f = BloomFilter::with_capacity(1000, 0.001);
        for k in 0..100u64 {
            f.insert(k);
        }
        assert_eq!(f.count_contained(0..100u64), 100);
        assert_eq!(f.count_contained(std::iter::empty::<u64>()), 0);
        // disjoint probe set: essentially none contained at 0.1% fpr
        let fp = f.count_contained((0..1000u64).map(|k| k + 1_000_000));
        assert!(fp < 20, "density over disjoint keys should be near zero, got {fp}");
    }
}
