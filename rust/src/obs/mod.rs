//! First-class observability: a zero-dependency metrics registry with
//! Prometheus text exposition ([`metrics`]) and a bounded binary flight
//! recorder ([`trace`]).
//!
//! Both layers are designed to stay on in production:
//!
//! * every hot-path update is a handful of relaxed atomic ops behind an
//!   `enabled()` check (`GRAPHMP_OBS=0` turns the whole subsystem into
//!   no-ops, and [`metrics::set_enabled`] flips it at runtime so the
//!   overhead bench can compare both modes in one process);
//! * nothing here may change results — the conformance suite reruns the
//!   engines with metrics + tracing fully enabled and asserts the value
//!   dumps are byte-identical (`tests/obs_conformance.rs`).
//!
//! The registry is scraped three ways: the `metrics` verb on the serve
//! line protocol, `graphmp client metrics`, and the daemon's optional
//! `--metrics-listen` plain-HTTP `GET /metrics` listener.  `graphmp top`
//! polls the same exposition and renders a live per-dataset view.

pub mod metrics;
pub mod trace;

/// Total resident overhead of the observability layer, charged into
/// `RunStats::memory_bytes` so Fig-11-style accounting stays honest.
pub fn overhead_bytes() -> u64 {
    metrics::overhead_bytes() + trace::overhead_bytes()
}
