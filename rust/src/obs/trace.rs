//! Flight recorder: a bounded binary log of per-run span records (GMTF).
//!
//! `--trace <path>` on `run`/`partrun`/`serve` installs a process-global
//! recorder.  The engine appends one [`TraceRecord::Iter`] per VSW
//! iteration (same fields as `IterStats`) and, at a configurable sample
//! rate, one [`TraceRecord::Shard`] per shard with the acquire → decode →
//! fold timing split.  Records are epoch/app-tagged by a
//! [`TraceRecord::Meta`] written at each run start.
//!
//! The recorder is ring-buffer capped so it can stay on in production:
//! the newest `cap` records are always retained, the file is appended per
//! record and rewritten from the ring once it grows past `2 × cap`
//! records, so the on-disk log is bounded at roughly twice the ring.
//! `graphmp trace-dump <path>` renders the log as text.
//!
//! ## GMTF format (version 1)
//!
//! ```text
//! header:  "GMTF" magic · u32 LE version
//! record:  u8 kind · payload
//!   kind 1 (meta):  u64 epoch · u32 sample · u32 app_len · app bytes
//!   kind 2 (iter):  13 × u64 LE   (see TraceRecord::Iter field order)
//!   kind 3 (shard):  5 × u64 LE   (iter, shard, acquire_ns, decode_ns, fold_ns)
//! ```
//!
//! All integers are little-endian.  Unknown kinds abort the decode, so
//! version bumps must change `VERSION`.

use crate::Result;
use anyhow::{bail, Context};
use std::collections::VecDeque;
use std::fs::File;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// File magic.
pub const MAGIC: [u8; 4] = *b"GMTF";
/// Format version written to the header.
pub const VERSION: u32 = 1;
/// Default ring capacity (records retained).
pub const DEFAULT_CAP: usize = 4096;
/// Default shard sample rate: every Nth shard gets a span record.
pub const DEFAULT_SAMPLE: u32 = 16;

/// One record in the flight-recorder log.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceRecord {
    /// Run start: which app, on which epoch, at what shard sample rate.
    Meta { app: String, epoch: u64, sample: u32 },
    /// One VSW iteration (mirror of `IterStats`, nanosecond clocks).
    Iter {
        epoch: u64,
        iter: u64,
        wall_ns: u64,
        io_wait_ns: u64,
        compute_ns: u64,
        decode_ns: u64,
        shards_processed: u64,
        shards_skipped: u64,
        active: u64,
        read_bytes: u64,
        cache_hits: u64,
        cache_misses: u64,
        window: u64,
    },
    /// Sampled per-shard span: acquire → decode → fold timing split.
    Shard { iter: u64, shard: u64, acquire_ns: u64, decode_ns: u64, fold_ns: u64 },
}

struct Recorder {
    path: PathBuf,
    file: File,
    cap: usize,
    ring: VecDeque<TraceRecord>,
    /// Records currently in the file; rewritten from the ring at `2*cap`.
    file_records: usize,
}

static INSTALLED: AtomicBool = AtomicBool::new(false);
static SAMPLE: AtomicU32 = AtomicU32::new(0);
static CAP: AtomicU64 = AtomicU64::new(0);
static TOTAL: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static RECORDER: Mutex<Option<Recorder>> = Mutex::new(None);

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn encode(rec: &TraceRecord, buf: &mut Vec<u8>) {
    match rec {
        TraceRecord::Meta { app, epoch, sample } => {
            buf.push(1);
            put_u64(buf, *epoch);
            put_u32(buf, *sample);
            put_u32(buf, app.len() as u32);
            buf.extend_from_slice(app.as_bytes());
        }
        TraceRecord::Iter {
            epoch,
            iter,
            wall_ns,
            io_wait_ns,
            compute_ns,
            decode_ns,
            shards_processed,
            shards_skipped,
            active,
            read_bytes,
            cache_hits,
            cache_misses,
            window,
        } => {
            buf.push(2);
            for v in [
                epoch,
                iter,
                wall_ns,
                io_wait_ns,
                compute_ns,
                decode_ns,
                shards_processed,
                shards_skipped,
                active,
                read_bytes,
                cache_hits,
                cache_misses,
                window,
            ] {
                put_u64(buf, *v);
            }
        }
        TraceRecord::Shard { iter, shard, acquire_ns, decode_ns, fold_ns } => {
            buf.push(3);
            for v in [iter, shard, acquire_ns, decode_ns, fold_ns] {
                put_u64(buf, *v);
            }
        }
    }
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.pos + n > self.data.len() {
            bail!("truncated trace record at byte {}", self.pos);
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn decode(cur: &mut Cursor<'_>) -> Result<TraceRecord> {
    let kind = cur.take(1)?[0];
    match kind {
        1 => {
            let epoch = cur.u64()?;
            let sample = cur.u32()?;
            let len = cur.u32()? as usize;
            let app = std::str::from_utf8(cur.take(len)?)
                .context("meta record app name is not UTF-8")?
                .to_string();
            Ok(TraceRecord::Meta { app, epoch, sample })
        }
        2 => Ok(TraceRecord::Iter {
            epoch: cur.u64()?,
            iter: cur.u64()?,
            wall_ns: cur.u64()?,
            io_wait_ns: cur.u64()?,
            compute_ns: cur.u64()?,
            decode_ns: cur.u64()?,
            shards_processed: cur.u64()?,
            shards_skipped: cur.u64()?,
            active: cur.u64()?,
            read_bytes: cur.u64()?,
            cache_hits: cur.u64()?,
            cache_misses: cur.u64()?,
            window: cur.u64()?,
        }),
        3 => Ok(TraceRecord::Shard {
            iter: cur.u64()?,
            shard: cur.u64()?,
            acquire_ns: cur.u64()?,
            decode_ns: cur.u64()?,
            fold_ns: cur.u64()?,
        }),
        k => bail!("unknown trace record kind {k}"),
    }
}

fn write_header(file: &mut File) -> Result<()> {
    file.write_all(&MAGIC)?;
    file.write_all(&VERSION.to_le_bytes())?;
    Ok(())
}

/// Install the flight recorder at `path`.  `cap` bounds the ring (0 uses
/// [`DEFAULT_CAP`]); `sample` is the shard sample rate (0 disables shard
/// spans).  Replaces any previously installed recorder.
pub fn install(path: &Path, cap: usize, sample: u32) -> Result<()> {
    let cap = if cap == 0 { DEFAULT_CAP } else { cap };
    let mut file = File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    write_header(&mut file)?;
    let rec = Recorder {
        path: path.to_path_buf(),
        file,
        cap,
        ring: VecDeque::with_capacity(cap.min(1 << 16)),
        file_records: 0,
    };
    *RECORDER.lock().unwrap() = Some(rec);
    SAMPLE.store(sample, Ordering::Relaxed);
    CAP.store(cap as u64, Ordering::Relaxed);
    INSTALLED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Whether a recorder is installed (cheap; checked before building records).
pub fn installed() -> bool {
    INSTALLED.load(Ordering::Relaxed)
}

/// Whether shard `shard` should get a span record this run.
pub fn shard_sampled(shard: u64) -> bool {
    if !installed() || !crate::obs::metrics::enabled() {
        return false;
    }
    let s = SAMPLE.load(Ordering::Relaxed);
    s > 0 && shard % s as u64 == 0
}

/// Append one record.  No-op unless installed and `GRAPHMP_OBS` is on.
pub fn record(rec: TraceRecord) {
    if !installed() || !crate::obs::metrics::enabled() {
        return;
    }
    let mut guard = RECORDER.lock().unwrap();
    let Some(r) = guard.as_mut() else { return };
    if r.ring.len() >= r.cap {
        r.ring.pop_front();
        DROPPED.fetch_add(1, Ordering::Relaxed);
    }
    let mut buf = Vec::with_capacity(128);
    encode(&rec, &mut buf);
    r.ring.push_back(rec);
    TOTAL.fetch_add(1, Ordering::Relaxed);
    if r.file.write_all(&buf).is_ok() {
        r.file_records += 1;
    }
    if r.file_records >= r.cap * 2 {
        // Rewrite the file from the ring so the on-disk log stays bounded.
        if let Ok(mut f) = File::create(&r.path) {
            if write_header(&mut f).is_ok() {
                let mut all = Vec::with_capacity(r.ring.len() * 64);
                for rec in &r.ring {
                    encode(rec, &mut all);
                }
                if f.write_all(&all).is_ok() {
                    r.file = f;
                    r.file_records = r.ring.len();
                }
            }
        }
    }
}

/// Convenience: tag the start of a run (app + epoch) in the log.
pub fn record_run_start(app: &str, epoch: u64) {
    if !installed() {
        return;
    }
    let sample = SAMPLE.load(Ordering::Relaxed);
    record(TraceRecord::Meta { app: app.to_string(), epoch, sample });
}

/// Flush and uninstall the recorder, returning its path if one was live.
pub fn finish() -> Option<PathBuf> {
    let mut guard = RECORDER.lock().unwrap();
    let rec = guard.take()?;
    INSTALLED.store(false, Ordering::Relaxed);
    let _ = rec.file.sync_all();
    Some(rec.path)
}

/// `(records written, records dropped by the ring cap)` — pull-collected
/// into the metrics registry.
pub fn totals() -> (u64, u64) {
    (TOTAL.load(Ordering::Relaxed), DROPPED.load(Ordering::Relaxed))
}

/// Approximate resident bytes of the trace ring.
pub fn overhead_bytes() -> u64 {
    if !installed() {
        return 0;
    }
    CAP.load(Ordering::Relaxed) * (std::mem::size_of::<TraceRecord>() as u64 + 16)
}

/// Decode every record in a GMTF file.
pub fn read_records(path: &Path) -> Result<Vec<TraceRecord>> {
    let data = std::fs::read(path)
        .with_context(|| format!("reading trace file {}", path.display()))?;
    if data.len() < 8 || data[..4] != MAGIC {
        bail!("{} is not a GMTF trace (bad magic)", path.display());
    }
    let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if version != VERSION {
        bail!("unsupported GMTF version {version} (expected {VERSION})");
    }
    let mut cur = Cursor { data: &data, pos: 8 };
    let mut out = Vec::new();
    while cur.pos < cur.data.len() {
        out.push(decode(&mut cur)?);
    }
    Ok(out)
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// Render one record as a text line (`graphmp trace-dump` output).
pub fn format_record(rec: &TraceRecord) -> String {
    match rec {
        TraceRecord::Meta { app, epoch, sample } => {
            format!("meta app={app} epoch={epoch} sample={sample}")
        }
        TraceRecord::Iter {
            epoch,
            iter,
            wall_ns,
            io_wait_ns,
            compute_ns,
            decode_ns,
            shards_processed,
            shards_skipped,
            active,
            read_bytes,
            cache_hits,
            cache_misses,
            window,
        } => format!(
            "iter epoch={epoch} iter={iter} wall_ms={:.3} io_wait_ms={:.3} compute_ms={:.3} \
             decode_ms={:.3} shards={shards_processed} skipped={shards_skipped} active={active} \
             read_bytes={read_bytes} hits={cache_hits} misses={cache_misses} window={window}",
            ms(*wall_ns),
            ms(*io_wait_ns),
            ms(*compute_ns),
            ms(*decode_ns),
        ),
        TraceRecord::Shard { iter, shard, acquire_ns, decode_ns, fold_ns } => format!(
            "shard iter={iter} shard={shard} acquire_us={:.1} decode_us={:.1} fold_us={:.1}",
            *acquire_ns as f64 / 1e3,
            *decode_ns as f64 / 1e3,
            *fold_ns as f64 / 1e3,
        ),
    }
}

/// Text dump of a whole trace file.
pub fn dump(path: &Path) -> Result<String> {
    let recs = read_records(path)?;
    let mut out = String::new();
    for r in &recs {
        out.push_str(&format_record(r));
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(recs: &[TraceRecord]) -> Vec<TraceRecord> {
        let mut buf = Vec::new();
        for r in recs {
            encode(r, &mut buf);
        }
        let mut cur = Cursor { data: &buf, pos: 0 };
        let mut out = Vec::new();
        while cur.pos < cur.data.len() {
            out.push(decode(&mut cur).unwrap());
        }
        out
    }

    #[test]
    fn encode_decode_roundtrip() {
        let recs = vec![
            TraceRecord::Meta { app: "pagerank".into(), epoch: 3, sample: 16 },
            TraceRecord::Iter {
                epoch: 3,
                iter: 0,
                wall_ns: 1_234_567,
                io_wait_ns: 400_000,
                compute_ns: 800_000,
                decode_ns: 120_000,
                shards_processed: 8,
                shards_skipped: 1,
                active: 71,
                read_bytes: 65_536,
                cache_hits: 2,
                cache_misses: 6,
                window: 4,
            },
            TraceRecord::Shard {
                iter: 0,
                shard: 16,
                acquire_ns: 52_000,
                decode_ns: 11_000,
                fold_ns: 90_000,
            },
        ];
        assert_eq!(roundtrip(&recs), recs);
    }

    #[test]
    fn truncated_input_is_an_error() {
        let mut buf = Vec::new();
        encode(
            &TraceRecord::Shard { iter: 0, shard: 1, acquire_ns: 2, decode_ns: 3, fold_ns: 4 },
            &mut buf,
        );
        buf.truncate(buf.len() - 1);
        let mut cur = Cursor { data: &buf, pos: 0 };
        assert!(decode(&mut cur).is_err());
    }
}
