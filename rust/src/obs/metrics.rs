//! Process-wide metrics registry with Prometheus text exposition.
//!
//! The registry is deliberately tiny: a fixed table of *descriptors*
//! (name, help, type) defined at compile time, and per-(metric, label
//! set) *series* created lazily on first touch.  Descriptors fix the
//! exposition order, so the rendered text is stable enough to golden-test
//! (`tests/obs_exposition.rs`).
//!
//! Hot-path cost: one relaxed atomic load for the enabled check, one
//! `Mutex` lock over a short `Vec` scan to resolve the series (callers on
//! per-iteration paths touch a handful of series per iteration, not per
//! edge), then relaxed atomic adds/stores.  Histogram sums are f64 bits
//! in an `AtomicU64` updated by a CAS loop.
//!
//! Two update idioms are used at the seams:
//!
//! * **push** — code that already computes a delta calls [`counter_add`]
//!   / [`observe_secs`] (per-iteration engine stats, barrier timings,
//!   admission rejections);
//! * **mirror** — subsystems that keep their own monotonic atomics
//!   (`ShardCache` stats, `uring` counts, `storage::io` totals) are
//!   copied in with [`counter_to`], a `fetch_max` so the exposition stays
//!   monotonic no matter how many engines share a family.
//!
//! `GRAPHMP_OBS=0` disables every update at startup; [`set_enabled`]
//! flips the same flag at runtime (the overhead bench measures both modes
//! in one process, and the conformance suite proves bit-invisibility).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Exposition content type (what a real Prometheus scraper expects).
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Shared latency ladder (seconds) for every histogram family.
pub const LATENCY_BUCKETS: &[f64] = &[0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0];

/// Metric kind, rendered as the `# TYPE` line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// How the raw `AtomicU64` backing a series is interpreted at render time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Unit {
    /// Plain integer count / bytes.
    Int,
    /// Accumulated nanoseconds, rendered as seconds.
    SecondsFromNanos,
    /// f64 bit pattern (gauges like active-ratio).
    Float,
}

struct Descriptor {
    name: &'static str,
    help: &'static str,
    kind: Kind,
    unit: Unit,
}

macro_rules! desc {
    ($name:literal, $kind:ident, $unit:ident, $help:literal) => {
        Descriptor { name: $name, help: $help, kind: Kind::$kind, unit: Unit::$unit }
    };
}

/// Every metric family this crate exports, in exposition order.  Adding a
/// family here is the *only* registration step; the golden exposition
/// test pins this table.
const DESCRIPTORS: &[Descriptor] = &[
    desc!("graphmp_io_read_bytes_total", Counter, Int, "Bytes read from storage (real files)"),
    desc!("graphmp_io_written_bytes_total", Counter, Int, "Bytes written to storage (real files)"),
    desc!("graphmp_io_read_ops_total", Counter, Int, "Storage read operations"),
    desc!("graphmp_io_write_ops_total", Counter, Int, "Storage write operations"),
    desc!(
        "graphmp_io_throttle_stall_seconds_total",
        Counter,
        SecondsFromNanos,
        "Time spent sleeping in the disk-throttle model"
    ),
    desc!("graphmp_cache_hits_total", Counter, Int, "Shard cache hits"),
    desc!("graphmp_cache_misses_total", Counter, Int, "Shard cache misses"),
    desc!("graphmp_cache_evictions_total", Counter, Int, "Shards evicted from the cache"),
    desc!(
        "graphmp_cache_invalidations_total",
        Counter,
        Int,
        "Cached shards invalidated by epoch refresh"
    ),
    desc!("graphmp_cache_resident_bytes", Gauge, Int, "Bytes currently resident in the shard cache"),
    desc!("graphmp_engine_iterations_total", Counter, Int, "VSW iterations executed"),
    desc!(
        "graphmp_engine_io_wait_seconds_total",
        Counter,
        SecondsFromNanos,
        "Time the compute side waited on shard I/O"
    ),
    desc!(
        "graphmp_engine_compute_seconds_total",
        Counter,
        SecondsFromNanos,
        "Time spent in gather/apply compute"
    ),
    desc!(
        "graphmp_engine_decode_seconds_total",
        Counter,
        SecondsFromNanos,
        "Time spent decoding / decompressing shard payloads"
    ),
    desc!("graphmp_engine_active_ratio", Gauge, Float, "Active-vertex ratio of the last iteration"),
    desc!("graphmp_engine_window", Gauge, Int, "Prefetch window planned by the I/O governor"),
    desc!("graphmp_engine_lent_bytes", Gauge, Int, "Cache bytes lent to the prefetcher"),
    desc!("graphmp_engine_epoch", Gauge, Int, "Epoch the engine last iterated on"),
    desc!("graphmp_iter_seconds", Histogram, Float, "Wall time per VSW iteration"),
    desc!(
        "graphmp_uring_direct_reads_total",
        Counter,
        Int,
        "Shard reads served by the O_DIRECT submission ring"
    ),
    desc!(
        "graphmp_uring_fallback_reads_total",
        Counter,
        Int,
        "Shard reads that fell back to buffered I/O"
    ),
    desc!("graphmp_uring_queue_depth", Gauge, Int, "Submission-ring queue depth (last planned)"),
    desc!("graphmp_sessions_open", Gauge, Int, "Open daemon sessions"),
    desc!("graphmp_engines_resident", Gauge, Int, "Resident VswEngine instances in the daemon"),
    desc!("graphmp_engines_evicted_total", Counter, Int, "Idle engines evicted by --engine-ttl-secs"),
    desc!("graphmp_requests_total", Counter, Int, "Daemon requests dispatched, by verb"),
    desc!(
        "graphmp_admission_busy_total",
        Counter,
        Int,
        "Requests rejected with err busy by admission control"
    ),
    desc!("graphmp_jobs_inflight", Gauge, Int, "Admitted jobs currently running, by class"),
    desc!("graphmp_jobs_queued", Gauge, Int, "Jobs waiting for an admission slot"),
    desc!(
        "graphmp_barrier_seconds",
        Histogram,
        Float,
        "Partition coordinator post-all/receive-all barrier latency"
    ),
    desc!(
        "graphmp_barrier_delta_lines_total",
        Counter,
        Int,
        "Delta lines exchanged across partition barriers"
    ),
    desc!("graphmp_part_stitch_bytes", Gauge, Int, "Coordinator stitch-buffer bytes (high water)"),
    desc!("graphmp_trace_records_total", Counter, Int, "Flight-recorder records written"),
    desc!("graphmp_trace_dropped_total", Counter, Int, "Flight-recorder records dropped by the ring cap"),
    desc!("graphmp_build_info", Gauge, Int, "Build/runtime capabilities (value is always 1)"),
];

/// One (metric, label set) time series.
struct Series {
    /// Label pairs exactly as registered, used for rendering and lookup.
    labels: Vec<(String, String)>,
    /// Counter / gauge cell, interpreted per the family's [`Unit`].
    value: AtomicU64,
    /// Histogram-only: one non-cumulative count per bucket + overflow.
    buckets: Vec<AtomicU64>,
    /// Histogram-only: f64 bits of the observation sum.
    sum_bits: AtomicU64,
    count: AtomicU64,
}

impl Series {
    fn new(labels: Vec<(String, String)>, histogram: bool) -> Self {
        let nb = if histogram { LATENCY_BUCKETS.len() + 1 } else { 0 };
        Series {
            labels,
            value: AtomicU64::new(0),
            buckets: (0..nb).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn label_text(&self, extra: Option<(&str, &str)>) -> String {
        if self.labels.is_empty() && extra.is_none() {
            return String::new();
        }
        let mut out = String::from("{");
        let mut first = true;
        for (k, v) in self.labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).chain(extra) {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(k);
            out.push_str("=\"");
            for c in v.chars() {
                match c {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    '\n' => out.push_str("\\n"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        out.push('}');
        out
    }
}

struct Family {
    desc: &'static Descriptor,
    series: Mutex<Vec<Arc<Series>>>,
}

struct Registry {
    families: Vec<Family>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        families: DESCRIPTORS
            .iter()
            .map(|d| Family { desc: d, series: Mutex::new(Vec::new()) })
            .collect(),
    })
}

fn enabled_flag() -> &'static AtomicBool {
    static FLAG: OnceLock<AtomicBool> = OnceLock::new();
    FLAG.get_or_init(|| {
        let on = std::env::var("GRAPHMP_OBS").map(|v| v != "0").unwrap_or(true);
        AtomicBool::new(on)
    })
}

/// Whether updates are recorded.  Defaults to on; `GRAPHMP_OBS=0` in the
/// environment starts the process with the registry disabled.
pub fn enabled() -> bool {
    enabled_flag().load(Ordering::Relaxed)
}

/// Runtime override of the `GRAPHMP_OBS` switch (the overhead bench
/// toggles this between warm runs inside one process).
pub fn set_enabled(on: bool) {
    enabled_flag().store(on, Ordering::Relaxed);
}

fn series(name: &str, labels: &[(&str, &str)]) -> Option<Arc<Series>> {
    let fam = registry().families.iter().find(|f| f.desc.name == name)?;
    let mut vec = fam.series.lock().unwrap();
    if let Some(s) = vec.iter().find(|s| {
        s.labels.len() == labels.len()
            && s.labels.iter().zip(labels).all(|((k, v), (lk, lv))| k == lk && v == lv)
    }) {
        return Some(Arc::clone(s));
    }
    let owned = labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    let s = Arc::new(Series::new(owned, fam.desc.kind == Kind::Histogram));
    vec.push(Arc::clone(&s));
    Some(s)
}

/// Add `delta` to a counter.  For `*_seconds_total` families the delta is
/// in nanoseconds.  No-op when disabled or the name is unknown.
pub fn counter_add(name: &str, labels: &[(&str, &str)], delta: u64) {
    if !enabled() || delta == 0 {
        return;
    }
    if let Some(s) = series(name, labels) {
        s.value.fetch_add(delta, Ordering::Relaxed);
    }
}

/// Raise a counter to an externally-tracked monotonic `total` (mirror
/// idiom — `fetch_max`, so repeated snapshots and multiple reporters can
/// never move the exposition backwards).
pub fn counter_to(name: &str, labels: &[(&str, &str)], total: u64) {
    if !enabled() {
        return;
    }
    if let Some(s) = series(name, labels) {
        s.value.fetch_max(total, Ordering::Relaxed);
    }
}

/// Set an integer gauge.
pub fn gauge_set(name: &str, labels: &[(&str, &str)], v: u64) {
    if !enabled() {
        return;
    }
    if let Some(s) = series(name, labels) {
        s.value.store(v, Ordering::Relaxed);
    }
}

/// Set a float gauge (families declared with a float unit).
pub fn gauge_set_f64(name: &str, labels: &[(&str, &str)], v: f64) {
    if !enabled() {
        return;
    }
    if let Some(s) = series(name, labels) {
        s.value.store(v.to_bits(), Ordering::Relaxed);
    }
}

fn f64_fetch_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Record one observation (seconds) into a histogram family.
pub fn observe_secs(name: &str, labels: &[(&str, &str)], secs: f64) {
    if !enabled() {
        return;
    }
    if let Some(s) = series(name, labels) {
        let idx = LATENCY_BUCKETS
            .iter()
            .position(|&le| secs <= le)
            .unwrap_or(LATENCY_BUCKETS.len());
        s.buckets[idx].fetch_add(1, Ordering::Relaxed);
        s.count.fetch_add(1, Ordering::Relaxed);
        f64_fetch_add(&s.sum_bits, secs);
    }
}

fn fmt_value(unit: Unit, raw: u64) -> String {
    match unit {
        Unit::Int => raw.to_string(),
        Unit::SecondsFromNanos => format!("{}", raw as f64 / 1e9),
        Unit::Float => format!("{}", f64::from_bits(raw)),
    }
}

/// Pull-collect subsystems that keep their own global atomics, so a
/// scrape sees current totals without any hot-path double accounting.
fn collect_pulls() {
    let io = crate::storage::io::snapshot();
    counter_to("graphmp_io_read_bytes_total", &[], io.bytes_read);
    counter_to("graphmp_io_written_bytes_total", &[], io.bytes_written);
    counter_to("graphmp_io_read_ops_total", &[], io.read_ops);
    counter_to("graphmp_io_write_ops_total", &[], io.write_ops);
    counter_to("graphmp_io_throttle_stall_seconds_total", &[], io.throttle_ns);
    let (records, dropped) = crate::obs::trace::totals();
    counter_to("graphmp_trace_records_total", &[], records);
    counter_to("graphmp_trace_dropped_total", &[], dropped);
    let simd = crate::engine::simd::level();
    let uring = crate::storage::uring::resolve_mode().name();
    gauge_set("graphmp_build_info", &[("simd", simd), ("uring", uring)], 1);
}

/// Render the full registry as Prometheus text format (v0.0.4).  Every
/// family gets its `# HELP` / `# TYPE` header even when no series exist
/// yet, so the exposed schema is stable; series render in creation order.
pub fn render() -> String {
    if enabled() {
        collect_pulls();
    }
    let mut out = String::with_capacity(4096);
    for fam in &registry().families {
        let d = fam.desc;
        out.push_str("# HELP ");
        out.push_str(d.name);
        out.push(' ');
        out.push_str(d.help);
        out.push('\n');
        out.push_str("# TYPE ");
        out.push_str(d.name);
        out.push(' ');
        out.push_str(d.kind.as_str());
        out.push('\n');
        let vec = fam.series.lock().unwrap();
        for s in vec.iter() {
            if d.kind == Kind::Histogram {
                let mut cum = 0u64;
                for (i, b) in s.buckets.iter().enumerate() {
                    cum += b.load(Ordering::Relaxed);
                    let le = if i < LATENCY_BUCKETS.len() {
                        format!("{}", LATENCY_BUCKETS[i])
                    } else {
                        "+Inf".to_string()
                    };
                    out.push_str(d.name);
                    out.push_str("_bucket");
                    out.push_str(&s.label_text(Some(("le", le.as_str()))));
                    out.push(' ');
                    out.push_str(&cum.to_string());
                    out.push('\n');
                }
                let sum = f64::from_bits(s.sum_bits.load(Ordering::Relaxed));
                out.push_str(d.name);
                out.push_str("_sum");
                out.push_str(&s.label_text(None));
                out.push(' ');
                out.push_str(&format!("{sum}"));
                out.push('\n');
                out.push_str(d.name);
                out.push_str("_count");
                out.push_str(&s.label_text(None));
                out.push(' ');
                out.push_str(&s.count.load(Ordering::Relaxed).to_string());
                out.push('\n');
            } else {
                out.push_str(d.name);
                out.push_str(&s.label_text(None));
                out.push(' ');
                out.push_str(&fmt_value(d.unit, s.value.load(Ordering::Relaxed)));
                out.push('\n');
            }
        }
    }
    out
}

/// Parse one exposition sample line into `(name, labels, value)`.
/// Returns `None` for comments, blank lines, and malformed input.  Used
/// by `graphmp top` and the format tests.
pub fn parse_line(line: &str) -> Option<(String, Vec<(String, String)>, f64)> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return None;
    }
    let (name_part, rest) = if let Some(open) = line.find('{') {
        let close = line.rfind('}')?;
        if close < open {
            return None;
        }
        (&line[..open], Some((&line[open + 1..close], &line[close + 1..])))
    } else {
        let sp = line.find(' ')?;
        (&line[..sp], None)
    };
    let mut labels = Vec::new();
    let value_str = match rest {
        Some((body, tail)) => {
            let mut chars = body.chars().peekable();
            while chars.peek().is_some() {
                let key: String = chars.by_ref().take_while(|&c| c != '=').collect();
                if chars.next() != Some('"') {
                    return None;
                }
                let mut val = String::new();
                loop {
                    match chars.next()? {
                        '\\' => match chars.next()? {
                            'n' => val.push('\n'),
                            c => val.push(c),
                        },
                        '"' => break,
                        c => val.push(c),
                    }
                }
                if key.is_empty() {
                    return None;
                }
                labels.push((key, val));
                if chars.peek() == Some(&',') {
                    chars.next();
                }
            }
            tail.trim()
        }
        None => line[name_part.len()..].trim(),
    };
    let value = if value_str == "+Inf" {
        f64::INFINITY
    } else {
        value_str.parse::<f64>().ok()?
    };
    if name_part.is_empty() {
        return None;
    }
    Some((name_part.to_string(), labels, value))
}

/// Approximate resident bytes held by the registry (descriptor table,
/// series cells, label strings) — charged into `RunStats::memory_bytes`.
pub fn overhead_bytes() -> u64 {
    let mut total = (DESCRIPTORS.len() * std::mem::size_of::<Family>()) as u64;
    for fam in &registry().families {
        let vec = fam.series.lock().unwrap();
        for s in vec.iter() {
            total += std::mem::size_of::<Series>() as u64;
            total += (s.buckets.len() * 8) as u64;
            for (k, v) in &s.labels {
                total += (k.len() + v.len()) as u64;
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The enabled flag is process-global; serialize tests that flip it.
    static GATE: Mutex<()> = Mutex::new(());

    fn gate() -> std::sync::MutexGuard<'static, ()> {
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn counters_accumulate_and_mirror_is_monotonic() {
        let _g = gate();
        set_enabled(true);
        counter_add("graphmp_barrier_delta_lines_total", &[("dataset", "unit-a")], 3);
        counter_add("graphmp_barrier_delta_lines_total", &[("dataset", "unit-a")], 4);
        counter_to("graphmp_cache_hits_total", &[("dataset", "unit-a")], 10);
        counter_to("graphmp_cache_hits_total", &[("dataset", "unit-a")], 7);
        let text = render();
        assert!(
            text.contains("graphmp_barrier_delta_lines_total{dataset=\"unit-a\"} 7"),
            "{text}"
        );
        assert!(text.contains("graphmp_cache_hits_total{dataset=\"unit-a\"} 10"), "{text}");
    }

    #[test]
    fn disabled_updates_are_dropped() {
        let _g = gate();
        set_enabled(true);
        counter_add("graphmp_admission_busy_total", &[("dataset", "unit-b")], 1);
        set_enabled(false);
        counter_add("graphmp_admission_busy_total", &[("dataset", "unit-b")], 99);
        set_enabled(true);
        let text = render();
        assert!(text.contains("graphmp_admission_busy_total{dataset=\"unit-b\"} 1"), "{text}");
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let _g = gate();
        set_enabled(true);
        let l = &[("dataset", "unit-h")];
        observe_secs("graphmp_iter_seconds", l, 0.0005);
        observe_secs("graphmp_iter_seconds", l, 0.01);
        observe_secs("graphmp_iter_seconds", l, 100.0);
        let text = render();
        assert!(
            text.contains("graphmp_iter_seconds_bucket{dataset=\"unit-h\",le=\"0.001\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("graphmp_iter_seconds_bucket{dataset=\"unit-h\",le=\"0.02\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("graphmp_iter_seconds_bucket{dataset=\"unit-h\",le=\"+Inf\"} 3"),
            "{text}"
        );
        assert!(text.contains("graphmp_iter_seconds_count{dataset=\"unit-h\"} 3"), "{text}");
    }

    #[test]
    fn parse_line_roundtrips() {
        let (name, labels, v) =
            parse_line("graphmp_cache_hits_total{dataset=\"tiny.gmp\"} 42").unwrap();
        assert_eq!(name, "graphmp_cache_hits_total");
        assert_eq!(labels, vec![("dataset".to_string(), "tiny.gmp".to_string())]);
        assert_eq!(v, 42.0);
        let (name, labels, v) = parse_line("graphmp_sessions_open 2").unwrap();
        assert_eq!(name, "graphmp_sessions_open");
        assert!(labels.is_empty());
        assert_eq!(v, 2.0);
        assert!(parse_line("# TYPE x counter").is_none());
        assert!(parse_line("").is_none());
    }
}
