//! Failure injection: corruption, truncation and inconsistency in a
//! preprocessed dataset must fail loudly at open/run time — never produce
//! silently-wrong results.

use graphmp::apps::PageRank;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::generator;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;

fn build(tag: &str) -> DatasetDir {
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("gmp_fi_{tag}_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let edges = generator::erdos_renyi(200, 2000, 5);
    preprocess(
        tag,
        &edges,
        200,
        &dir,
        &PreprocessConfig { max_edges_per_shard: 256, bloom_fpr: 0.01 },
    )
    .unwrap();
    dir
}

fn open_and_run(dir: DatasetDir) -> anyhow::Result<()> {
    // cache disabled so shard reads happen lazily during run (exercising the
    // run-time read path, not just open-time warming)
    let engine = VswEngine::open(
        dir,
        EngineConfig { cache_budget: 0, max_iters: 2, ..Default::default() },
    )?;
    engine.run(&PageRank::default())?;
    Ok(())
}

#[test]
fn clean_dataset_runs() {
    let dir = build("clean");
    open_and_run(dir).expect("clean dataset must run");
}

#[test]
fn bitflipped_shard_is_detected() {
    let dir = build("flip");
    let shard = dir.shard_path(1);
    let mut bytes = std::fs::read(&shard).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&shard, bytes).unwrap();
    let err = open_and_run(dir).expect_err("bitflip must be detected");
    let msg = format!("{err:#}");
    assert!(msg.to_lowercase().contains("crc"), "unexpected error: {msg}");
}

#[test]
fn truncated_shard_is_detected() {
    let dir = build("trunc");
    let shard = dir.shard_path(0);
    let bytes = std::fs::read(&shard).unwrap();
    std::fs::write(&shard, &bytes[..bytes.len() / 2]).unwrap();
    assert!(open_and_run(dir).is_err());
}

#[test]
fn missing_shard_is_detected() {
    let dir = build("missing");
    std::fs::remove_file(dir.shard_path(0)).unwrap();
    assert!(open_and_run(dir).is_err());
}

#[test]
fn corrupt_bloom_is_detected_at_open() {
    let dir = build("bloom");
    let bloom = dir.bloom_path(0);
    let mut bytes = std::fs::read(&bloom).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&bloom, bytes).unwrap();
    assert!(
        VswEngine::open(dir, EngineConfig::default()).is_err(),
        "corrupt bloom must fail open()"
    );
}

#[test]
fn vertexinfo_property_mismatch_is_detected() {
    let dir = build("mismatch");
    // swap in a vertexinfo from a smaller graph
    let other = build("mismatch_other_src");
    let small_edges = generator::erdos_renyi(50, 200, 6);
    let small = DatasetDir::new(
        std::env::temp_dir().join(format!("gmp_fi_small_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&small.root);
    preprocess("s", &small_edges, 50, &small, &PreprocessConfig::default()).unwrap();
    std::fs::copy(small.vertexinfo_path(), dir.vertexinfo_path()).unwrap();
    let _ = other;
    assert!(VswEngine::open(dir, EngineConfig::default()).is_err());
}

#[test]
fn tampered_property_intervals_rejected() {
    let dir = build("prop");
    let text = std::fs::read_to_string(dir.property_path()).unwrap();
    // break monotonicity of the interval list
    let bad = text.replacen("\"intervals\":[0,", "\"intervals\":[5,", 1);
    assert_ne!(text, bad, "fixture should contain the interval header");
    std::fs::write(dir.property_path(), bad).unwrap();
    assert!(VswEngine::open(dir, EngineConfig::default()).is_err());
}
