//! Full-pipeline integration through the public API exactly as the CLI
//! drives it: generate → write edge list → preprocess → open → run each
//! app → check cross-app invariants on a power-law multigraph.

use graphmp::apps::{Bfs, PageRank, SpMv, Sssp, VertexProgram, Wcc};
use graphmp::coordinator::datasets::Dataset;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::edgelist;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;

fn build_pipeline() -> (DatasetDir, usize) {
    let d = Dataset::by_name("tiny").unwrap();
    let edges = d.generate();
    let tmp = std::env::temp_dir().join(format!("gmp_cli_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();

    // exercise the on-disk interchange (binary edge list) like the CLI does
    let el = tmp.join("edges.bin");
    edgelist::write_binary(&el, &edges).unwrap();
    let edges = edgelist::read_auto(&el).unwrap();

    let dir = DatasetDir::new(tmp.join("data.gmp"));
    preprocess("tiny", &edges, d.num_vertices(), &dir, &PreprocessConfig::default()).unwrap();
    (dir, d.num_vertices())
}

fn run(dir: &DatasetDir, app: &dyn VertexProgram, iters: usize) -> Vec<f32> {
    let cfg = EngineConfig { max_iters: iters, ..Default::default() };
    let engine = VswEngine::open(dir.clone(), cfg).unwrap();
    engine.run(app).unwrap().values
}

#[test]
fn all_apps_run_and_satisfy_invariants() {
    let (dir, n) = build_pipeline();

    // PageRank: all positive, bounded by 1
    let pr = run(&dir, &PageRank::default(), 10);
    assert_eq!(pr.len(), n);
    assert!(pr.iter().all(|&r| r > 0.0 && r < 1.0));

    // SSSP and BFS agree on unweighted graphs
    let sssp = run(&dir, &Sssp { source: 0 }, 0);
    let bfs = run(&dir, &Bfs { root: 0 }, 0);
    assert_eq!(sssp, bfs, "unit-weight SSSP must equal BFS levels");
    assert_eq!(sssp[0], 0.0);

    // WCC labels are component-minimal: label[v] <= v
    let wcc = run(&dir, &Wcc, 0);
    for (v, &c) in wcc.iter().enumerate() {
        assert!(c <= v as f32, "label above own id at {v}");
    }

    // SpMV: y = A^T x  — total mass preserved modulo out-degree weighting
    let spmv = run(&dir, &SpMv { seed: 7 }, 1);
    assert_eq!(spmv.len(), n);
    assert!(spmv.iter().all(|v| v.is_finite()));
}

#[test]
fn rerunning_on_same_dataset_is_deterministic() {
    let (dir, _) = build_pipeline();
    let a = run(&dir, &PageRank::default(), 5);
    let b = run(&dir, &PageRank::default(), 5);
    assert_eq!(a, b);
}

#[test]
fn thread_count_does_not_change_results() {
    let (dir, _) = build_pipeline();
    let mut results = Vec::new();
    for threads in [1usize, 2, 8] {
        let engine = VswEngine::open(
            dir.clone(),
            EngineConfig { max_iters: 6, threads, ..Default::default() },
        )
        .unwrap();
        results.push(engine.run(&PageRank::default()).unwrap().values);
    }
    assert_eq!(results[0], results[1], "1 vs 2 threads");
    assert_eq!(results[1], results[2], "2 vs 8 threads");
}
