//! Integration: the native and xla (three-layer AOT) backends must produce
//! identical results through the full preprocess→run pipeline, for every
//! app, with selective scheduling and caching active.
//!
//! This is the proof that the L3/L2/L1 composition is semantics-preserving:
//! the PJRT path exercises artifacts produced by `python/compile/aot.py`
//! from the Pallas kernels.

use std::path::PathBuf;
use std::sync::Arc;

use graphmp::apps::{PageRank, Sssp, VertexProgram, Wcc};
use graphmp::engine::{Backend, EngineConfig, VswEngine};
use graphmp::graph::generator;
use graphmp::runtime::ShardRuntime;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn build_dataset(tag: &str) -> (DatasetDir, usize) {
    let n = 1 << 9; // 512 vertices
    let edges = generator::rmat(9, 4000, generator::RmatParams::default(), 77);
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("gmp_eq_{tag}_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let cfg = PreprocessConfig { max_edges_per_shard: 1500, bloom_fpr: 0.01 };
    preprocess(tag, &edges, n, &dir, &cfg).unwrap();
    (dir, n)
}

fn run_both(app: &dyn VertexProgram, max_iters: usize) -> (Vec<f32>, Vec<f32>, u64) {
    let Some(adir) = artifact_dir() else {
        eprintln!("SKIP: artifacts/ not built");
        return (vec![], vec![], 1);
    };
    let rt = Arc::new(ShardRuntime::load(&adir).expect("artifacts"));
    let (dir, _) = build_dataset(app.name());

    let native = VswEngine::open(
        dir.clone(),
        EngineConfig { max_iters, threads: 2, ..Default::default() },
    )
    .unwrap();
    let a = native.run(app).unwrap();

    let xla = VswEngine::open(
        dir,
        EngineConfig {
            max_iters,
            threads: 2,
            backend: Backend::Xla(rt.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    let b = xla.run(app).unwrap();
    let calls = rt.call_count();
    (a.values, b.values, calls)
}

#[test]
fn pagerank_native_equals_xla() {
    let (a, b, calls) = run_both(&PageRank::default(), 5);
    if a.is_empty() {
        return; // skipped
    }
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        // accumulation order differs (one-hot matmul vs sequential fold):
        // allow f32 round-off only
        assert!(
            (x - y).abs() <= 1e-5 * x.abs().max(1e-6),
            "v{i}: native {x} vs xla {y}"
        );
    }
    assert!(calls > 0, "xla backend never invoked the PJRT kernels");
}

#[test]
fn sssp_native_equals_xla_exactly() {
    let (a, b, calls) = run_both(&Sssp { source: 3 }, 0);
    if a.is_empty() {
        return;
    }
    // min-monoid is order-insensitive in f32: results must be bit-identical
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(
            (x.is_infinite() && y.is_infinite()) || x == y,
            "v{i}: native {x} vs xla {y}"
        );
    }
    assert!(calls > 0);
}

#[test]
fn wcc_native_equals_xla_exactly() {
    let (a, b, calls) = run_both(&Wcc, 0);
    if a.is_empty() {
        return;
    }
    assert_eq!(a, b);
    assert!(calls > 0);
}
