//! The cross-engine conformance matrix: every registered app — the five
//! classic f32 programs plus the typed-lane apps (weighted SSSP f32,
//! labelprop u64, maxdeg u32, spmv64 f64) — must agree with the
//! single-threaded in-memory oracle across
//!
//! * the VSW engine under {selective on/off} × threads {1,2,4} ×
//!   prefetch {0,2} plus the adaptive I/O governor, and
//! * all five out-of-core baselines (PSW/ESG/DSW/VSP/in-mem),
//!
//! on one deterministic *weighted* dataset.  Comparison is **bit-exact**
//! everywhere except the two engines that legitimately reorder a
//! Sum-monoid reduction (ESG's update files and DSW's grid blocks permute
//! f32 additions; they get a float tolerance on Sum apps only).  Min/Max
//! monoids are order-insensitive, so the three new apps must be
//! bit-identical on *every* engine — the acceptance bar of the typed
//! vertex-state API.
//!
//! The second half keeps the original native-vs-xla equivalence tests
//! (skipped unless `artifacts/` is built): the proof that the L3/L2/L1
//! composition is semantics-preserving.

use std::path::PathBuf;
use std::sync::Arc;

use graphmp::apps::{
    Bfs, LabelProp, MaxDeg, PageRank, ProgramContext, Reduce, SpMv, SpMv64, Sssp, VertexProgram,
    VertexValue, Wcc, WeightedSssp,
};
use graphmp::baselines::run_typed_by_name;
use graphmp::engine::{Backend, EngineConfig, VswEngine};
use graphmp::graph::{generator, Edge, Weight};
use graphmp::runtime::ShardRuntime;
use graphmp::sharding::{preprocess_weighted, PreprocessConfig};
use graphmp::storage::DatasetDir;

const N: usize = 128;
const THREADS: [usize; 3] = [1, 2, 4];
const DEPTHS: [usize; 2] = [0, 2];
const BASELINES: [&str; 5] = ["psw", "esg", "dsw", "vsp", "inmem"];

/// The conformance graph: deterministic, symmetrized, weighted.
fn conformance_graph() -> (Vec<Edge>, Vec<Weight>) {
    let mut edges = generator::rmat(7, 600, generator::RmatParams::default(), 77);
    let rev: Vec<_> = edges.iter().map(|&(s, d)| (d, s)).collect();
    edges.extend(rev);
    let weights = generator::synth_weights(&edges, 5);
    (edges, weights)
}

fn build_dataset(tag: &str, edges: &[Edge], weights: &[Weight]) -> DatasetDir {
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("gmp_conf_{tag}_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let cfg = PreprocessConfig { max_edges_per_shard: 256, bloom_fpr: 0.01 };
    preprocess_weighted(tag, edges, weights, N, &dir, &cfg).unwrap();
    dir
}

/// Single-threaded in-memory oracle: Algorithm 2 swept synchronously with
/// explicit per-in-edge weights, on any value lane.
fn reference<V: VertexValue>(
    app: &dyn VertexProgram<V>,
    edges: &[Edge],
    weights: &[Weight],
    n: usize,
    max_iters: usize,
) -> Vec<V> {
    let ctx = ProgramContext { num_vertices: n as u64 };
    let mut in_adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut in_w: Vec<Vec<Weight>> = vec![Vec::new(); n];
    let mut out_deg = vec![0u32; n];
    for (k, &(s, d)) in edges.iter().enumerate() {
        in_adj[d as usize].push(s);
        in_w[d as usize].push(weights[k]);
        out_deg[s as usize] += 1;
    }
    let mut vals: Vec<V> = (0..n).map(|v| app.init(v as u32, &ctx)).collect();
    for _ in 0..max_iters {
        let next: Vec<V> = (0..n)
            .map(|v| app.update_weighted(v as u32, &in_adj[v], &in_w[v], &vals, &out_deg, &ctx))
            .collect();
        let changed = next
            .iter()
            .zip(&vals)
            .any(|(&a, &b)| V::changed(b, a, 0.0));
        vals = next;
        if !changed {
            break;
        }
    }
    vals
}

fn assert_exact<V: VertexValue>(got: &[V], want: &[V], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(a == b, "{what} v{i}: {a:?} vs {b:?}");
    }
}

fn assert_tolerant<V: VertexValue>(got: &[V], want: &[V], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let (x, y) = (a.approx_f64(), b.approx_f64());
        if x.is_infinite() && y.is_infinite() {
            continue;
        }
        assert!(
            (x - y).abs() <= 1e-4 * y.abs().max(1e-6),
            "{what} v{i}: {x} vs {y}"
        );
    }
}

/// Run one app through the full matrix.  `engine_iters = 0` means "to
/// convergence" (the app's own default cap).
fn conformance<V: VertexValue>(
    tag: &str,
    app: &dyn VertexProgram<V>,
    engine_iters: usize,
    ref_iters: usize,
) {
    let (edges, weights) = conformance_graph();
    let dir = build_dataset(tag, &edges, &weights);
    let want = reference(app, &edges, &weights, N, ref_iters);
    // Sum reductions are order-sensitive in float; ESG/DSW legitimately
    // permute them.  Min/Max (and every integer lane) must be bit-exact on
    // every engine.
    let sum_monoid = app.reduce() == Reduce::Sum;

    // --- VSW: selective × threads × prefetch, plus the adaptive governor —
    // all bit-exact (the engine preserves the oracle's per-row gather order)
    let mut configs: Vec<(bool, usize, usize, bool)> = Vec::new();
    for selective in [false, true] {
        for &threads in &THREADS {
            for &depth in &DEPTHS {
                configs.push((selective, threads, depth, false));
            }
        }
    }
    configs.push((true, 4, 2, true)); // adaptive governor
    for (selective, threads, depth, adaptive) in configs {
        let engine = VswEngine::open(
            dir.clone(),
            EngineConfig {
                max_iters: engine_iters,
                threads,
                selective,
                selective_threshold: 0.05,
                prefetch_depth: depth,
                adaptive,
                prefetch_max: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let got = engine.run(app).unwrap();
        assert_exact(
            &got.values,
            &want,
            &format!("{tag} vsw sel={selective} t={threads} d={depth} adaptive={adaptive}"),
        );
    }

    // --- all five baselines through the typed dispatch -------------------
    let iters = if engine_iters == 0 { 10_000 } else { engine_iters };
    for sys in BASELINES {
        let work = std::env::temp_dir()
            .join(format!("gmp_conf_base_{sys}_{tag}_{}", std::process::id()));
        let run = run_typed_by_name(sys, work, &edges, &weights, N, app, iters).unwrap();
        let what = format!("{tag} {sys}");
        if sum_monoid && matches!(sys, "esg" | "dsw") {
            assert_tolerant(&run.values, &want, &what);
        } else {
            assert_exact(&run.values, &want, &what);
        }
    }
    let _ = std::fs::remove_dir_all(&dir.root);
}

#[test]
fn conformance_pagerank() {
    conformance::<f32>("pagerank", &PageRank::default(), 8, 8);
}

#[test]
fn conformance_sssp() {
    conformance::<f32>("sssp", &Sssp { source: 0 }, 0, 10_000);
}

#[test]
fn conformance_wcc() {
    conformance::<f32>("wcc", &Wcc, 0, 10_000);
}

#[test]
fn conformance_bfs() {
    conformance::<f32>("bfs", &Bfs { root: 0 }, 0, 10_000);
}

#[test]
fn conformance_spmv() {
    conformance::<f32>("spmv", &SpMv { seed: 1 }, 1, 1);
}

#[test]
fn conformance_spmv64_f64_lane() {
    conformance::<f64>("spmv64", &SpMv64 { seed: 1 }, 1, 1);
}

#[test]
fn conformance_weighted_sssp() {
    // the weight lane itself: distances must reflect real val(u,v), and
    // min-monoid exactness holds on every engine
    conformance::<f32>("wsssp", &WeightedSssp { source: 0 }, 0, 10_000);
}

#[test]
fn conformance_labelprop_u64_lane() {
    conformance::<u64>("labelprop", &LabelProp, 0, 10_000);
}

#[test]
fn conformance_maxdeg_u32_lane() {
    conformance::<u32>("maxdeg", &MaxDeg, 0, 10_000);
}

#[test]
fn weighted_sssp_differs_from_unit_sssp_here() {
    // sanity that the weight lane is actually live in the matrix: on the
    // conformance graph (weights in {0.25..2.0}), weighted and unit
    // distances must differ somewhere reachable
    let (edges, weights) = conformance_graph();
    let w = reference::<f32>(&WeightedSssp { source: 0 }, &edges, &weights, N, 10_000);
    let u = reference::<f32>(&Sssp { source: 0 }, &edges, &weights, N, 10_000);
    assert!(
        w.iter().zip(&u).any(|(a, b)| a.is_finite() && b.is_finite() && a != b),
        "synthetic weights never changed a distance — weight lane inert?"
    );
}

// ---- native vs xla (the original three-layer equivalence proof) ------------

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn run_both(app: &dyn VertexProgram, max_iters: usize) -> (Vec<f32>, Vec<f32>, u64) {
    let Some(adir) = artifact_dir() else {
        eprintln!("SKIP: artifacts/ not built");
        return (vec![], vec![], 1);
    };
    let rt = Arc::new(ShardRuntime::load(&adir).expect("artifacts"));
    let (edges, weights) = conformance_graph();
    let dir = build_dataset(&format!("xla_{}", app.name()), &edges, &weights);

    let native = VswEngine::open(
        dir.clone(),
        EngineConfig { max_iters, threads: 2, ..Default::default() },
    )
    .unwrap();
    let a = native.run(app).unwrap();

    let xla = VswEngine::open(
        dir,
        EngineConfig {
            max_iters,
            threads: 2,
            backend: Backend::Xla(rt.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    let b = xla.run(app).unwrap();
    let calls = rt.call_count();
    (a.values, b.values, calls)
}

#[test]
fn pagerank_native_equals_xla() {
    let (a, b, calls) = run_both(&PageRank::default(), 5);
    if a.is_empty() {
        return; // skipped
    }
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        // accumulation order differs (one-hot matmul vs sequential fold):
        // allow f32 round-off only
        assert!(
            (x - y).abs() <= 1e-5 * x.abs().max(1e-6),
            "v{i}: native {x} vs xla {y}"
        );
    }
    assert!(calls > 0, "xla backend never invoked the PJRT kernels");
}

#[test]
fn sssp_native_equals_xla_exactly() {
    let (a, b, calls) = run_both(&Sssp { source: 3 }, 0);
    if a.is_empty() {
        return;
    }
    // min-monoid is order-insensitive in f32: results must be bit-identical
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(
            (x.is_infinite() && y.is_infinite()) || x == y,
            "v{i}: native {x} vs xla {y}"
        );
    }
    assert!(calls > 0);
}

#[test]
fn weighted_sssp_native_equals_xla_exactly() {
    // the weight lane through the AOT relaxmin artifact: weights fold into
    // the rust-side gather, so the f32 path must stay bit-identical
    let (a, b, calls) = run_both(&WeightedSssp { source: 3 }, 0);
    if a.is_empty() {
        return;
    }
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(
            (x.is_infinite() && y.is_infinite()) || x == y,
            "v{i}: native {x} vs xla {y}"
        );
    }
    assert!(calls > 0);
}

#[test]
fn wcc_native_equals_xla_exactly() {
    let (a, b, calls) = run_both(&Wcc, 0);
    if a.is_empty() {
        return;
    }
    assert_eq!(a, b);
    assert!(calls > 0);
}

#[test]
fn typed_lanes_fall_back_to_native_under_xla_backend() {
    // a u64 program under Backend::Xla must run (native fallback), not fail
    let Some(adir) = artifact_dir() else {
        eprintln!("SKIP: artifacts/ not built");
        return;
    };
    let rt = Arc::new(ShardRuntime::load(&adir).expect("artifacts"));
    let (edges, weights) = conformance_graph();
    let dir = build_dataset("xla_lp", &edges, &weights);
    let engine = VswEngine::open(
        dir,
        EngineConfig { threads: 2, backend: Backend::Xla(rt), ..Default::default() },
    )
    .unwrap();
    let app: &dyn VertexProgram<u64> = &LabelProp;
    let got = engine.run(app).unwrap();
    let want = reference(app, &edges, &weights, N, 10_000);
    assert_eq!(got.values, want);
}
