//! Black-box tests of the `graphmp` binary itself: the generate →
//! preprocess → info → run → baseline flow a user follows, driven through
//! real process invocations.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_graphmp"))
}

fn workdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("gmp_clibin_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_lists_subcommands() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["generate", "preprocess", "run", "baseline", "info", "datasets"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn datasets_prints_registry() {
    let out = bin().arg("datasets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("twitter-s") && text.contains("eu2015-s"));
}

#[test]
fn full_user_flow() {
    let d = workdir();
    let edges = d.join("tiny.bin");
    let data = d.join("tiny.gmp");

    let out = bin()
        .args(["generate", "--dataset", "tiny", "--out"])
        .arg(&edges)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin()
        .args(["preprocess", "--input"])
        .arg(&edges)
        .args(["--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin().args(["info", "--data"]).arg(&data).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("edges:       4000"), "{text}");

    let out = bin()
        .args(["run", "--data"])
        .arg(&data)
        .args(["--app", "pagerank", "--iters", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("iters=3"), "{text}");

    let out = bin()
        .args(["baseline", "--system", "dsw", "--data"])
        .arg(&edges)
        .args(["--app", "wcc", "--iters", "20"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("gridgraph"));
}

#[test]
fn bad_inputs_fail_with_nonzero_exit() {
    // unknown dataset
    let out = bin()
        .args(["generate", "--dataset", "nope", "--out", "/tmp/x"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown dataset"));

    // run on a non-dataset
    let out = bin()
        .args(["run", "--data", "/definitely/not/there", "--app", "pr"])
        .output()
        .unwrap();
    assert!(!out.status.success());

    // missing required flag
    let out = bin().args(["preprocess"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn symmetrize_doubles_edges() {
    let d = workdir();
    let edges = d.join("sym.bin");
    let data = d.join("sym.gmp");
    bin()
        .args(["generate", "--dataset", "tiny", "--out"])
        .arg(&edges)
        .output()
        .unwrap();
    bin()
        .args(["preprocess", "--symmetrize", "--input"])
        .arg(&edges)
        .args(["--out"])
        .arg(&data)
        .output()
        .unwrap();
    let out = bin().args(["info", "--data"]).arg(&data).output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("edges:       8000"), "{text}");
}
