//! Black-box tests of the `graphmp` binary itself: the generate →
//! preprocess → info → run → baseline flow a user follows, driven through
//! real process invocations.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_graphmp"))
}

fn workdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("gmp_clibin_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_lists_subcommands() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in [
        "generate",
        "preprocess",
        "run",
        "baseline",
        "info",
        "datasets",
        "ingest",
        "compact",
        "mutate-gen",
        "serve",
        "client",
    ] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn mutation_flow_ingest_incremental_compact() {
    let d = workdir().join("mutflow");
    std::fs::create_dir_all(&d).unwrap();
    let edges = d.join("edges.bin");
    let data = d.join("data.gmp");
    let _ = std::fs::remove_dir_all(&data);
    let run_ok = |args: &mut Command| {
        let out = args.output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        out
    };
    run_ok(bin().args(["generate", "--dataset", "tiny", "--out"]).arg(&edges));
    run_ok(bin().args(["preprocess", "--input"]).arg(&edges).args(["--out"]).arg(&data));

    // batch 1: inserts + tombstone deletes, from the text form
    let b1 = d.join("b1.txt");
    std::fs::write(&b1, "+ 3 7\n+ 9 7\n- 3 7\n+ 1 2\n").unwrap();
    let out = run_ok(bin().args(["ingest", "--data"]).arg(&data).args(["--batch"]).arg(&b1));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("epoch=1"), "{text}");

    // run + save the fixpoint, dump values
    let v1 = d.join("v1.txt");
    run_ok(
        bin()
            .args(["run", "--data"])
            .arg(&data)
            .args(["--app", "wcc", "--save-values", "--dump-values"])
            .arg(&v1),
    );

    // batch 2: insert-only (synthetic), then incremental vs cold agree
    let b2 = d.join("b2.gmdl");
    run_ok(
        bin()
            .args(["mutate-gen", "--data"])
            .arg(&data)
            .args(["--count", "100", "--seed", "3", "--delete-fraction", "0", "--out"])
            .arg(&b2),
    );
    run_ok(bin().args(["ingest", "--data"]).arg(&data).args(["--batch"]).arg(&b2));
    let warm = d.join("warm.txt");
    let cold = d.join("cold.txt");
    let out = run_ok(
        bin()
            .args(["run", "--data"])
            .arg(&data)
            .args(["--app", "wcc", "--incremental", "--dump-values"])
            .arg(&warm),
    );
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("warm start"),
        "incremental run must report the warm path"
    );
    run_ok(
        bin()
            .args(["run", "--data"])
            .arg(&data)
            .args(["--app", "wcc", "--dump-values"])
            .arg(&cold),
    );
    assert_eq!(
        std::fs::read(&warm).unwrap(),
        std::fs::read(&cold).unwrap(),
        "incremental and cold dumps must be byte-identical"
    );

    // compact all, results unchanged; info reports the epoch chain
    run_ok(bin().args(["compact", "--data"]).arg(&data).args(["--all"]));
    let after = d.join("after.txt");
    run_ok(
        bin()
            .args(["run", "--data"])
            .arg(&data)
            .args(["--app", "wcc", "--dump-values"])
            .arg(&after),
    );
    assert_eq!(std::fs::read(&cold).unwrap(), std::fs::read(&after).unwrap());
    let out = run_ok(bin().args(["info", "--data"]).arg(&data));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("epoch:"), "{text}");
}

#[test]
fn datasets_prints_registry() {
    let out = bin().arg("datasets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("twitter-s") && text.contains("eu2015-s"));
}

#[test]
fn full_user_flow() {
    let d = workdir();
    let edges = d.join("tiny.bin");
    let data = d.join("tiny.gmp");

    let out = bin()
        .args(["generate", "--dataset", "tiny", "--out"])
        .arg(&edges)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin()
        .args(["preprocess", "--input"])
        .arg(&edges)
        .args(["--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin().args(["info", "--data"]).arg(&data).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("edges:       4000"), "{text}");

    let out = bin()
        .args(["run", "--data"])
        .arg(&data)
        .args(["--app", "pagerank", "--iters", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("iters=3"), "{text}");

    let out = bin()
        .args(["baseline", "--system", "dsw", "--data"])
        .arg(&edges)
        .args(["--app", "wcc", "--iters", "20"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("gridgraph"));
}

#[test]
fn bad_inputs_fail_with_nonzero_exit() {
    // unknown dataset
    let out = bin()
        .args(["generate", "--dataset", "nope", "--out", "/tmp/x"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown dataset"));

    // run on a non-dataset
    let out = bin()
        .args(["run", "--data", "/definitely/not/there", "--app", "pr"])
        .output()
        .unwrap();
    assert!(!out.status.success());

    // missing required flag
    let out = bin().args(["preprocess"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn symmetrize_doubles_edges() {
    let d = workdir();
    let edges = d.join("sym.bin");
    let data = d.join("sym.gmp");
    bin()
        .args(["generate", "--dataset", "tiny", "--out"])
        .arg(&edges)
        .output()
        .unwrap();
    bin()
        .args(["preprocess", "--symmetrize", "--input"])
        .arg(&edges)
        .args(["--out"])
        .arg(&data)
        .output()
        .unwrap();
    let out = bin().args(["info", "--data"]).arg(&data).output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("edges:       8000"), "{text}");
}

#[test]
fn adaptive_run_flag_works_and_reports_window() {
    let d = workdir();
    let edges = d.join("adpt.bin");
    let data = d.join("adpt.gmp");
    bin()
        .args(["generate", "--dataset", "tiny", "--out"])
        .arg(&edges)
        .output()
        .unwrap();
    bin()
        .args(["preprocess", "--input"])
        .arg(&edges)
        .args(["--out"])
        .arg(&data)
        .output()
        .unwrap();
    let out = bin()
        .args(["run", "--data"])
        .arg(&data)
        .args(["--app", "pagerank", "--iters", "3", "--adaptive", "--prefetch-max", "4"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("iters=3"), "{text}");
    assert!(text.contains("window="), "per-iteration dump must show the window: {text}");
}

#[test]
fn bench_compare_gates_regressions() {
    let d = workdir();
    let base = d.join("BENCH_baseline.json");
    let cur = d.join("BENCH_pr.json");
    std::fs::write(
        &base,
        r#"{"b1":{"wall_secs":2.0,"io_wait_fraction":0.2,"cache_hit_ratio":0.9}}"#,
    )
    .unwrap();
    // within tolerance: +10%
    std::fs::write(
        &cur,
        r#"{"b1":{"wall_secs":2.2,"io_wait_fraction":0.25,"cache_hit_ratio":0.9}}"#,
    )
    .unwrap();
    let out = bin()
        .args(["bench-compare", "--baseline"])
        .arg(&base)
        .args(["--current"])
        .arg(&cur)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("within"), "pass summary expected");

    // past tolerance AND past the absolute floor: must fail
    std::fs::write(&cur, r#"{"b1":{"wall_secs":9.0}}"#).unwrap();
    let out = bin()
        .args(["bench-compare", "--baseline"])
        .arg(&base)
        .args(["--current"])
        .arg(&cur)
        .output()
        .unwrap();
    assert!(!out.status.success(), "regression must exit nonzero");
    assert!(String::from_utf8_lossy(&out.stderr).contains("regression"));

    // missing bench in current: must fail
    std::fs::write(&cur, r#"{}"#).unwrap();
    let out = bin()
        .args(["bench-compare", "--baseline"])
        .arg(&base)
        .args(["--current"])
        .arg(&cur)
        .output()
        .unwrap();
    assert!(!out.status.success(), "missing bench must exit nonzero");
}
