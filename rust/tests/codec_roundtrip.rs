//! Roundtrip/fuzz-style property tests for the shard cache codecs
//! (`cache::codec`, paper modes 1-4 + extensions), the CSR-structural
//! delta-varint codec (`cache::deltavarint`), the weighted shard format
//! (v1→v2 compatibility included) and the lane-tagged `VertexInfo`
//! payloads: random edge lists, empty / single-edge / duplicate-heavy
//! shards, arbitrary byte blobs, truncation, all four value lanes.

use graphmp::cache::{deltavarint, Codec};
use graphmp::graph::csr::Csr;
use graphmp::graph::{AnyValues, Degrees, VertexValue};
use graphmp::storage::{shardfile, vertexinfo::VertexInfo};
use graphmp::util::prop::{self, Gen};

/// Random shard: arbitrary interval, duplicate-friendly edge list, weight
/// lane half the time.
fn random_shard(g: &mut Gen) -> Csr {
    let lo = g.usize_in(0, 200) as u32;
    let width = g.usize_in(1, 96) as u32;
    let m = g.usize_in(0, 500);
    // duplicate-heavy half the time: draw sources from a tiny pool
    let src_pool = if g.bool(0.5) { 4 } else { 100_000 };
    let edges: Vec<(u32, u32)> = (0..m)
        .map(|_| {
            (
                g.usize_in(0, src_pool) as u32,
                lo + g.usize_in(0, width as usize) as u32,
            )
        })
        .collect();
    let weights: Vec<f32> = if g.bool(0.5) {
        (0..m).map(|_| (g.usize_in(1, 64) as f32) * 0.125).collect()
    } else {
        Vec::new()
    };
    Csr::from_edges_weighted(lo, lo + width, &edges, &weights)
}

fn edge_multiset(csr: &Csr) -> Vec<(u32, u32)> {
    let mut e = csr.to_edges();
    e.sort_unstable();
    e
}

/// `(src, dst, weight-bits)` multiset — the weight lane must survive every
/// codec bit-for-bit, attached to the same edge.
fn wedge_multiset(csr: &Csr) -> Vec<(u32, u32, u32)> {
    let mut e: Vec<(u32, u32, u32)> = csr
        .to_wedges()
        .into_iter()
        .map(|(s, d, w)| (s, d, w.to_bits()))
        .collect();
    e.sort_unstable();
    e
}

#[test]
fn prop_all_codecs_roundtrip_random_shards() {
    prop::check(0xC0DEC, 40, |g| {
        let csr = random_shard(g);
        let payload = shardfile::to_bytes(&csr);
        let want = wedge_multiset(&csr);
        for codec in Codec::ALL {
            let compressed = codec.compress(&payload).unwrap();
            let back = codec.decompress_shard(&compressed).unwrap();
            back.validate().unwrap();
            assert_eq!((back.lo, back.hi), (csr.lo, csr.hi), "{}", codec.name());
            assert_eq!(back.is_weighted(), csr.is_weighted(), "{}", codec.name());
            assert_eq!(wedge_multiset(&back), want, "codec {}", codec.name());
        }
    });
}

#[test]
fn prop_v1_shard_payloads_load_through_every_codec() {
    // the v1→v2 compatibility path: legacy unweighted payloads must decode
    // through the byte codecs and the cache's shard entry point unchanged
    prop::check(0x1001, 30, |g| {
        let lo = g.usize_in(0, 50) as u32;
        let width = g.usize_in(1, 40) as u32;
        let m = g.usize_in(0, 200);
        let edges: Vec<(u32, u32)> = (0..m)
            .map(|_| {
                (
                    g.usize_in(0, 500) as u32,
                    lo + g.usize_in(0, width as usize) as u32,
                )
            })
            .collect();
        let csr = Csr::from_edges(lo, lo + width, &edges);
        let v1 = shardfile::to_bytes_v1(&csr);
        // direct load
        let back = shardfile::from_bytes(&v1).unwrap();
        assert_eq!(back, csr);
        assert!(!back.is_weighted());
        // through every byte codec (DeltaVarint re-parses the payload, so
        // it exercises the v1 reader too)
        for codec in Codec::ALL {
            let compressed = codec.compress(&v1).unwrap();
            let decoded = codec.decompress_shard(&compressed).unwrap();
            assert_eq!(edge_multiset(&decoded), edge_multiset(&csr), "{}", codec.name());
        }
    });
}

#[test]
fn prop_vertexinfo_payloads_roundtrip_every_lane() {
    fn lane_values<V: VertexValue>(g: &mut Gen, n: usize, f: fn(u64) -> V) -> Vec<V> {
        (0..n).map(|_| f(g.u64())).collect()
    }
    prop::check(0x71FE, 40, |g| {
        let n = g.usize_in(0, 200);
        let degrees = Degrees {
            in_deg: (0..n).map(|_| g.usize_in(0, 1000) as u32).collect(),
            out_deg: (0..n).map(|_| g.usize_in(0, 1000) as u32).collect(),
        };
        let values = match g.usize_in(0, 5) {
            0 => AnyValues::U32(lane_values(g, n, |x| x as u32)),
            1 => AnyValues::U64(lane_values(g, n, |x| x)),
            2 => AnyValues::F32(lane_values(g, n, |x| (x >> 40) as f32 * 0.5)),
            3 => AnyValues::F64(lane_values(g, n, |x| (x >> 20) as f64 * 0.25)),
            _ => AnyValues::default(), // empty values stay legal
        };
        let vi = VertexInfo { degrees, values };
        let bytes = vi.to_bytes();
        let back = VertexInfo::from_bytes(&bytes).unwrap();
        assert_eq!(back.degrees.in_deg, vi.degrees.in_deg);
        assert_eq!(back.degrees.out_deg, vi.degrees.out_deg);
        if vi.values.is_empty() {
            assert!(back.values.is_empty());
        } else {
            assert_eq!(back.values, vi.values);
        }
        // truncation anywhere must fail loudly
        let cut = g.usize_in(0, bytes.len());
        if cut < bytes.len() {
            assert!(VertexInfo::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
    });
}

#[test]
fn paper_modes_handle_degenerate_shards() {
    let cases: Vec<(&str, Csr)> = vec![
        ("empty", Csr::from_edges(3, 10, &[])),
        ("single-edge", Csr::from_edges(0, 1, &[(42, 0)])),
        (
            "duplicate-heavy",
            Csr::from_edges(5, 8, &vec![(7u32, 6u32); 300]),
        ),
        (
            "one-hot-row",
            Csr::from_edges(0, 64, &(0..500u32).map(|i| (i, 13)).collect::<Vec<_>>()),
        ),
    ];
    for (tag, csr) in &cases {
        let payload = shardfile::to_bytes(csr);
        let want = edge_multiset(csr);
        // the paper's four modes, plus the extensions for good measure
        for codec in Codec::ALL {
            let compressed = codec.compress(&payload).unwrap();
            let back = codec.decompress_shard(&compressed).unwrap();
            assert_eq!(edge_multiset(&back), want, "{tag} via {}", codec.name());
        }
    }
}

#[test]
fn prop_byte_codecs_roundtrip_arbitrary_blobs() {
    // the byte-oriented modes must invert compress on *any* input, not just
    // shard payloads (DeltaVarint is CSR-structural and excluded)
    let byte_codecs = [Codec::None, Codec::SnapLite, Codec::Zlib1, Codec::Zlib3, Codec::Zstd1];
    prop::check(0xB10B, 40, |g| {
        let n = g.usize_in(0, 8192);
        let mut data = Vec::with_capacity(n);
        while data.len() < n {
            if g.bool(0.4) {
                // a run (compressible)
                let b = g.u64() as u8;
                let len = g.usize_in(1, 128).min(n - data.len());
                data.extend(std::iter::repeat_n(b, len));
            } else {
                data.push(g.u64() as u8);
            }
        }
        for codec in byte_codecs {
            let c = codec.compress(&data).unwrap();
            assert_eq!(codec.decompress(&c).unwrap(), data, "codec {}", codec.name());
        }
    });
}

#[test]
fn prop_deltavarint_roundtrips_and_rejects_truncation() {
    prop::check(0xD17A, 30, |g| {
        let csr = random_shard(g);
        let buf = deltavarint::encode(&csr);
        let back = deltavarint::decode(&buf).unwrap();
        assert_eq!(edge_multiset(&back), edge_multiset(&csr));
        // every per-row source list comes back sorted
        for (_, srcs) in back.iter_rows() {
            assert!(srcs.windows(2).all(|w| w[0] <= w[1]), "row not sorted");
        }
        // truncations must never decode successfully
        if !buf.is_empty() {
            let cut = g.usize_in(0, buf.len());
            if cut < buf.len() {
                assert!(
                    deltavarint::decode(&buf[..cut]).is_err(),
                    "accepted truncation at {cut}/{}",
                    buf.len()
                );
            }
        }
    });
}

#[test]
fn prop_dv_cursor_api_streams_what_decode_materializes() {
    // the compressed-domain cursor API: a chunked plan + cursor walk over
    // any random shard's payload must visit exactly the rows/sources/
    // weight-bits the decoder materializes, for any chunk size, and the
    // plan must reject every truncation the decoder rejects
    prop::check(0xDC0DE, 30, |g| {
        let csr = random_shard(g);
        let buf = deltavarint::encode(&csr);
        let decoded = deltavarint::decode(&buf).unwrap();
        let chunk_rows = [0usize, 1, 3, 17, 4096][g.usize_in(0, 5)];
        let plan = deltavarint::plan(&buf, chunk_rows).unwrap();
        assert_eq!(plan.lo, decoded.lo);
        assert_eq!(plan.num_rows, decoded.num_vertices());
        assert_eq!(plan.num_edges, decoded.num_edges());
        assert_eq!(plan.weighted, decoded.is_weighted());
        let mut triples: Vec<(usize, u32, u32)> = Vec::new();
        for chunk in &plan.chunks {
            let mut cur = plan.cursor(&buf, chunk);
            for row in chunk.start_row..chunk.end_row {
                cur.next_row(|s, w| triples.push((row, s, w.to_bits()))).unwrap();
            }
        }
        let want: Vec<(usize, u32, u32)> = (0..decoded.num_vertices())
            .flat_map(|i| {
                (decoded.row_ptr[i] as usize..decoded.row_ptr[i + 1] as usize)
                    .map(move |k| (i, decoded.col[k], decoded.weight(k).to_bits()))
            })
            .collect();
        assert_eq!(triples, want, "chunk_rows={chunk_rows}");
        if !buf.is_empty() {
            let cut = g.usize_in(0, buf.len());
            if cut < buf.len() {
                assert!(deltavarint::plan(&buf[..cut], chunk_rows).is_err(), "cut {cut}");
            }
        }
    });
}

#[test]
fn prop_compressed_domain_gather_equals_decoded_every_codec_and_lane() {
    // the tentpole's correctness bar: for every codec, the engine-side
    // row stream built from the *compressed* representation must fold to
    // bit-identical per-vertex results as the decoded-CSR stream — on all
    // four value lanes, weighted and unweighted, at random chunk splits
    use graphmp::apps::{
        LabelProp, MaxDeg, PageRank, ProgramContext, SpMv64, VertexProgram, WeightedSssp,
    };
    use graphmp::engine::{process_rows, CsrRows, DvRows, ViewRows};

    /// Bit-exact view of a value array (PartialEq would conflate 0.0 and
    /// -0.0 on float lanes; the wire format cannot).
    fn wire<V: VertexValue>(vals: &[V]) -> Vec<u8> {
        let mut out = Vec::with_capacity(vals.len() * V::BYTES);
        for &v in vals {
            v.write_le(&mut out);
        }
        out
    }

    fn fold_sources<V: VertexValue>(
        app: &dyn VertexProgram<V>,
        csr: &Csr,
        src: &[V],
        out_deg: &[u32],
        chunk_rows: usize,
    ) {
        let ctx = ProgramContext { num_vertices: src.len() as u64 };
        let n = csr.num_vertices();
        let step = chunk_rows.max(1);
        // oracle: the decoded-CSR stream, whole shard in one chunk
        let mut want = vec![V::vzero(); n];
        process_rows(app, &mut CsrRows::new(csr, 0..n), src, out_deg, &ctx, &mut want)
            .unwrap();

        // serialized payload walked in place (what byte-codec hits and
        // raw disk reads use), chunked
        let payload = shardfile::to_bytes(csr);
        let layout = shardfile::parse_layout(&payload).unwrap();
        let mut got = vec![V::vzero(); n];
        for a in (0..n).step_by(step) {
            let b = (a + step).min(n);
            let mut rows = ViewRows::new(layout.view(&payload), a..b);
            process_rows(app, &mut rows, src, out_deg, &ctx, &mut got[a..b]).unwrap();
        }
        assert_eq!(wire(&want), wire(&got), "ViewRows diverged ({}, chunk {step})", app.name());

        // delta-varint streamed in the compressed domain, chunked; its
        // oracle is the decoded-dv CSR (dv sorts rows)
        let dv = deltavarint::encode(csr);
        let dv_csr = deltavarint::decode(&dv).unwrap();
        let mut dv_want = vec![V::vzero(); n];
        process_rows(app, &mut CsrRows::new(&dv_csr, 0..n), src, out_deg, &ctx, &mut dv_want)
            .unwrap();
        let plan = deltavarint::plan(&dv, step).unwrap();
        let mut dv_got = vec![V::vzero(); n];
        for chunk in &plan.chunks {
            let mut rows = DvRows::new(
                plan.cursor(&dv, chunk),
                plan.lo,
                chunk.start_row,
                chunk.end_row - chunk.start_row,
            );
            process_rows(
                app,
                &mut rows,
                src,
                out_deg,
                &ctx,
                &mut dv_got[chunk.start_row..chunk.end_row],
            )
            .unwrap();
        }
        assert_eq!(
            wire(&dv_want),
            wire(&dv_got),
            "DvRows diverged ({}, chunk {step})",
            app.name()
        );
    }

    prop::check(0x5EA7, 12, |g| {
        let csr = random_shard(g);
        let max_id = 100_000 + 1; // random_shard draws sources up to this
        let chunk_rows = [1usize, 4, 33, 4096][g.usize_in(0, 4)];
        let out_deg: Vec<u32> = (0..max_id).map(|_| (g.u64() % 9) as u32).collect();
        let src32: Vec<f32> = (0..max_id).map(|_| (g.u64() >> 44) as f32 * 0.5).collect();
        fold_sources::<f32>(&PageRank::default(), &csr, &src32, &out_deg, chunk_rows);
        fold_sources::<f32>(&WeightedSssp { source: 0 }, &csr, &src32, &out_deg, chunk_rows);
        let srcu64: Vec<u64> = (0..max_id as u64).collect();
        fold_sources::<u64>(&LabelProp, &csr, &srcu64, &out_deg, chunk_rows);
        let srcu32: Vec<u32> = (0..max_id as u32).collect();
        fold_sources::<u32>(&MaxDeg, &csr, &srcu32, &out_deg, chunk_rows);
        let srcf64: Vec<f64> = (0..max_id).map(|_| (g.u64() >> 40) as f64 * 0.25).collect();
        fold_sources::<f64>(&SpMv64::default(), &csr, &srcf64, &out_deg, chunk_rows);
    });
}

#[test]
fn compressing_codecs_shrink_a_realistic_shard() {
    // power-law-ish shard: the compression claim the cache's mode ablation
    // rests on must hold for every non-identity codec
    let edges: Vec<(u32, u32)> = (0..6000u32)
        .map(|i| ((i * i % 997) as u32, i % 512))
        .collect();
    let csr = Csr::from_edges(0, 512, &edges);
    let payload = shardfile::to_bytes(&csr);
    for codec in [Codec::SnapLite, Codec::Zlib1, Codec::Zlib3, Codec::Zstd1, Codec::DeltaVarint] {
        let c = codec.compress(&payload).unwrap();
        assert!(
            c.len() < payload.len(),
            "{} did not shrink: {} vs {}",
            codec.name(),
            c.len(),
            payload.len()
        );
    }
}
