//! The partitioned-execution conformance matrix: a `partrun`-style
//! coordinator driving in-process workers must produce values **byte-
//! identical** to the single-process VSW engine —
//!
//! * for all nine registered apps (every value lane),
//! * for N ∈ {2, 4} workers, balanced and deliberately uneven splits,
//! * with the adaptive I/O governor on or off inside the workers,
//!
//! plus the failure half of the contract: a worker that dies
//! mid-iteration must surface as a clean coordinator error naming the
//! worker, never as a hung barrier.  A final black-box test runs the real
//! `graphmp partrun` binary (separate worker *processes* over Unix
//! sockets) and `cmp`s its `--dump-values` file against `graphmp run`'s.
#![cfg(unix)]

use graphmp::apps;
use graphmp::cluster::{worker, Coordinator, PartitionManifest, StreamLink};
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::{generator, Edge, Weight};
use graphmp::sharding::{preprocess_weighted, PreprocessConfig};
use graphmp::storage::property::Property;
use graphmp::storage::DatasetDir;

const N: usize = 128;
const APPS: [&str; 9] = [
    "pagerank",
    "sssp",
    "wcc",
    "bfs",
    "spmv",
    "spmv64",
    "weighted-sssp",
    "labelprop",
    "maxdeg",
];

/// Same deterministic symmetrized weighted graph as the cross-engine
/// matrix, sharded fine (128 edges/shard) so 4 workers all own several
/// shards.
fn build_dataset(tag: &str) -> DatasetDir {
    let mut edges: Vec<Edge> = generator::rmat(7, 600, generator::RmatParams::default(), 77);
    let rev: Vec<_> = edges.iter().map(|&(s, d)| (d, s)).collect();
    edges.extend(rev);
    let weights: Vec<Weight> = generator::synth_weights(&edges, 5);
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("gmp_partconf_{tag}_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let cfg = PreprocessConfig { max_edges_per_shard: 128, bloom_fpr: 0.01 };
    preprocess_weighted(tag, &edges, &weights, N, &dir, &cfg).unwrap();
    dir
}

fn num_shards(dir: &DatasetDir) -> usize {
    Property::load(&dir.property_path()).unwrap().num_shards()
}

/// The single-process truth: one engine, `run_any`, bit-rendered lines.
fn reference_lines(dir: &DatasetDir, app_name: &str, cfg: &EngineConfig) -> Vec<String> {
    let engine = VswEngine::open(dir.clone(), cfg.clone()).unwrap();
    let app = apps::by_name(app_name).unwrap();
    let res = engine.run_any(&app).unwrap();
    (0..res.values.len()).map(|v| res.values.render_bits(v).unwrap()).collect()
}

/// A full partitioned run over in-process workers (socketpair + thread per
/// part — the same protocol bytes as spawned `partworker` processes).
fn partitioned_lines(
    dir: &DatasetDir,
    manifest: PartitionManifest,
    app_name: &str,
    cfg: &EngineConfig,
) -> Vec<String> {
    let mut links = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..manifest.num_parts() {
        let (stream, handle) = worker::spawn_local(dir.clone(), cfg.clone(), None).unwrap();
        links.push(StreamLink::new(stream));
        handles.push(handle);
    }
    let mut coord = Coordinator::new(manifest, links).unwrap();
    let summary = coord.run(app_name, cfg.max_iters, true).unwrap();
    drop(coord);
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert_eq!(summary.vertices, N);
    summary.values
}

fn assert_identical(got: &[String], want: &[String], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (v, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a, b, "{what}: vertex {v} diverged from the single-process run");
    }
}

#[test]
fn every_app_is_bit_identical_across_worker_counts() {
    let dir = build_dataset("apps");
    let p = num_shards(&dir);
    assert!(p >= 4, "conformance graph must span at least 4 shards, got {p}");
    let cfg = EngineConfig { threads: 1, ..Default::default() };
    for app in APPS {
        let want = reference_lines(&dir, app, &cfg);
        for workers in [2, 4] {
            let manifest = PartitionManifest::balanced(p, workers).unwrap();
            let got = partitioned_lines(&dir, manifest, app, &cfg);
            assert_identical(&got, &want, &format!("{app} N={workers}"));
        }
    }
    let _ = std::fs::remove_dir_all(&dir.root);
}

#[test]
fn uneven_splits_and_adaptive_workers_stay_bit_identical() {
    let dir = build_dataset("uneven");
    let p = num_shards(&dir);
    // worker 0 gets a single shard, worker 2 gets the long tail
    let manifest = || PartitionManifest::from_boundaries(p, &[1, 3]).unwrap();
    for (app, adaptive) in
        [("pagerank", false), ("pagerank", true), ("weighted-sssp", true), ("labelprop", false)]
    {
        let cfg = EngineConfig {
            threads: 1,
            adaptive,
            prefetch_depth: if adaptive { 2 } else { 0 },
            prefetch_max: 4,
            ..Default::default()
        };
        let want = reference_lines(&dir, app, &cfg);
        let got = partitioned_lines(&dir, manifest(), app, &cfg);
        assert_identical(&got, &want, &format!("{app} uneven adaptive={adaptive}"));
    }
    let _ = std::fs::remove_dir_all(&dir.root);
}

#[test]
fn selective_scheduling_engages_identically_in_partitioned_runs() {
    // sssp's frontier shrinks below the selective threshold mid-run, so
    // this exercises the digest/screening path across a partition
    let dir = build_dataset("selective");
    let p = num_shards(&dir);
    for selective in [false, true] {
        let cfg = EngineConfig { threads: 1, selective, ..Default::default() };
        let want = reference_lines(&dir, "sssp", &cfg);
        let got =
            partitioned_lines(&dir, PartitionManifest::balanced(p, 3).unwrap(), "sssp", &cfg);
        assert_identical(&got, &want, &format!("sssp selective={selective}"));
    }
    let _ = std::fs::remove_dir_all(&dir.root);
}

#[test]
fn worker_crash_mid_iteration_is_a_clean_error_not_a_hang() {
    let dir = build_dataset("crash");
    let p = num_shards(&dir);
    let manifest = PartitionManifest::balanced(p, 2).unwrap();
    let cfg = EngineConfig { threads: 1, ..Default::default() };
    let mut links = Vec::new();
    let mut handles = Vec::new();
    for i in 0..manifest.num_parts() {
        // worker 1 dies on the part-step carrying iteration 1, with the
        // response unsent
        let crash = (i == 1).then_some(1);
        let (stream, handle) = worker::spawn_local(dir.clone(), cfg.clone(), crash).unwrap();
        links.push(StreamLink::new(stream));
        handles.push(handle);
    }
    let mut coord = Coordinator::new(manifest, links).unwrap();
    let err = coord.run("pagerank", 0, true).expect_err("a dead worker must fail the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 1"), "error must name the dead worker: {msg}");
    drop(coord);
    // worker 0 sees EOF and exits clean; worker 1 reports the injected crash
    assert!(handles.remove(0).join().unwrap().is_ok());
    let crashed = handles.remove(0).join().unwrap();
    assert!(format!("{:#}", crashed.unwrap_err()).contains("injected worker crash"));
    let _ = std::fs::remove_dir_all(&dir.root);
}

#[test]
fn partrun_binary_dump_matches_run_dump_byte_for_byte() {
    use std::process::Command;
    let dir = build_dataset("binary");
    let single = dir.root.with_extension("single.txt");
    let parted = dir.root.with_extension("parted.txt");
    let run_ok = |args: &mut Command| {
        let out = args.output().unwrap();
        assert!(
            out.status.success(),
            "stdout: {}\nstderr: {}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        out
    };
    run_ok(
        Command::new(env!("CARGO_BIN_EXE_graphmp"))
            .args(["run", "--data"])
            .arg(&dir.root)
            .args(["--app", "pagerank", "--dump-values"])
            .arg(&single),
    );
    run_ok(
        Command::new(env!("CARGO_BIN_EXE_graphmp"))
            .args(["partrun", "--data"])
            .arg(&dir.root)
            .args(["--app", "pagerank", "--workers", "2", "--dump-values"])
            .arg(&parted),
    );
    let a = std::fs::read(&single).unwrap();
    let b = std::fs::read(&parted).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "partrun --dump-values must cmp clean against run --dump-values");

    // a crash-injected child surfaces as a coordinator error, not a hang
    let out = Command::new(env!("CARGO_BIN_EXE_graphmp"))
        .args(["partrun", "--data"])
        .arg(&dir.root)
        .args(["--app", "pagerank", "--workers", "2"])
        .env("GRAPHMP_PART_CRASH_ITER", "1")
        .env("GRAPHMP_PART_CRASH_WORKER", "1")
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("worker 1"), "stderr must name the dead worker: {stderr}");

    let _ = std::fs::remove_file(&single);
    let _ = std::fs::remove_file(&parted);
    let _ = std::fs::remove_dir_all(&dir.root);
}
