//! Integration: all five baseline engines and the VSW engine converge to
//! the same fixpoints on the same graph — the precondition for any of the
//! paper's cross-system comparisons to be meaningful.

use graphmp::apps::{PageRank, ProgramContext, Sssp, VertexProgram, Wcc};
use graphmp::baselines::{DswEngine, EsgEngine, InMemEngine, OocEngine, PswEngine, VspEngine};
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::generator;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;

const N: usize = 256;

fn edges() -> Vec<(u32, u32)> {
    let mut e = generator::rmat(8, 2500, generator::RmatParams::default(), 314);
    // symmetrize so WCC components are well-defined and SSSP reaches more
    let rev: Vec<_> = e.iter().map(|&(s, d)| (d, s)).collect();
    e.extend(rev);
    e
}

fn baseline_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("gmp_conv_{tag}_{}", std::process::id()))
}

fn engines() -> Vec<Box<dyn OocEngine>> {
    vec![
        Box::new(PswEngine::new(baseline_dir("psw"))),
        Box::new(EsgEngine::new(baseline_dir("esg"))),
        Box::new(DswEngine::new(baseline_dir("dsw"))),
        Box::new(VspEngine::new(baseline_dir("vsp"))),
        Box::new(InMemEngine::new()),
    ]
}

fn vsw_run(app: &dyn VertexProgram, max_iters: usize) -> Vec<f32> {
    let dir = DatasetDir::new(baseline_dir("vsw"));
    let _ = std::fs::remove_dir_all(&dir.root);
    preprocess(
        "conv",
        &edges(),
        N,
        &dir,
        &PreprocessConfig { max_edges_per_shard: 1024, bloom_fpr: 0.01 },
    )
    .unwrap();
    let engine = VswEngine::open(dir, EngineConfig { max_iters, ..Default::default() }).unwrap();
    engine.run(app).unwrap().values
}

#[test]
fn all_engines_agree_on_pagerank() {
    let want = vsw_run(&PageRank::default(), 8);
    let e = edges();
    for mut eng in engines() {
        eng.prepare(&e, N).unwrap();
        let run = eng.run(&PageRank::default(), 8).unwrap();
        assert_eq!(run.values.len(), N, "{}", eng.name());
        for (i, (a, b)) in run.values.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * b.abs().max(1e-6),
                "{} v{i}: {a} vs {b}",
                eng.name()
            );
        }
    }
}

#[test]
fn all_engines_agree_on_sssp() {
    let app = Sssp { source: 0 };
    let want = vsw_run(&app, 0);
    let e = edges();
    for mut eng in engines() {
        eng.prepare(&e, N).unwrap();
        let run = eng.run(&app, 500).unwrap();
        for (i, (a, b)) in run.values.iter().zip(&want).enumerate() {
            assert!(
                (a.is_infinite() && b.is_infinite()) || a == b,
                "{} v{i}: {a} vs {b}",
                eng.name()
            );
        }
    }
}

#[test]
fn all_engines_agree_on_wcc() {
    let want = vsw_run(&Wcc, 0);
    let e = edges();
    for mut eng in engines() {
        eng.prepare(&e, N).unwrap();
        let run = eng.run(&Wcc, 500).unwrap();
        assert_eq!(run.values, want, "{}", eng.name());
    }
}

#[test]
fn io_ordering_matches_table2_shape() {
    // per-iteration read volume: PSW > ESG > {DSW, VSP} > VSW(cached)=0
    let e = edges();
    let app = PageRank::default();
    let mut read_per_iter = std::collections::BTreeMap::new();
    for mut eng in engines() {
        eng.prepare(&e, N).unwrap();
        let run = eng.run(&app, 4).unwrap();
        if run.iter_io.len() >= 2 {
            // skip iter 0 (cold); measure steady state
            read_per_iter.insert(eng.name().to_string(), run.iter_io[1].bytes_read);
        }
    }
    let psw = read_per_iter["psw(graphchi)"];
    let esg = read_per_iter["esg(x-stream)"];
    let vsp = read_per_iter["vsp(venus)"];
    let inm = read_per_iter["inmem(graphmat)"];
    assert!(psw > esg, "PSW {psw} should out-read ESG {esg}");
    assert!(esg > vsp, "ESG {esg} should out-read VSP {vsp}");
    assert_eq!(inm, 0, "in-memory engine must not touch disk");

    // VSW with full cache: zero steady-state reads
    let ctx = ProgramContext { num_vertices: N as u64 };
    let _ = ctx;
    let dir = DatasetDir::new(baseline_dir("vsw_io"));
    let _ = std::fs::remove_dir_all(&dir.root);
    preprocess("io", &e, N, &dir, &PreprocessConfig::default()).unwrap();
    let engine = VswEngine::open(dir, EngineConfig { max_iters: 4, ..Default::default() }).unwrap();
    let run = engine.run(&app).unwrap();
    assert_eq!(run.stats.iters[1].io.bytes_read, 0, "VSW cached should read 0");
}

/// The governor's hottest-first idea extended to the baselines (ROADMAP
/// "fair adaptive comparisons" item): enabling heat-ordered read-ahead
/// must be bit-invisible in every engine's results — it only reorders
/// which independent chunk is streamed first.
#[test]
fn adaptive_order_is_bit_invisible_in_every_baseline() {
    let e = edges();
    let apps: Vec<Box<dyn VertexProgram>> = vec![
        Box::new(PageRank::default()),
        Box::new(Sssp { source: 0 }),
        Box::new(Wcc),
    ];
    for app in &apps {
        // PSW
        let mut a = PswEngine::new(baseline_dir("psw_ao"));
        a.prepare(&e, N).unwrap();
        let base = a.run(app.as_ref(), 8).unwrap();
        let mut b = PswEngine::new(baseline_dir("psw_ao"));
        b.set_adaptive_order(true);
        b.prepare(&e, N).unwrap();
        let hot = b.run(app.as_ref(), 8).unwrap();
        assert_eq!(base.values, hot.values, "psw {}", app.name());
        assert_eq!(base.io.bytes_read, hot.io.bytes_read, "psw bytes {}", app.name());

        // ESG (gather phase reorders; scatter order is the fold order)
        let mut a = EsgEngine::new(baseline_dir("esg_ao"));
        a.prepare(&e, N).unwrap();
        let base = a.run(app.as_ref(), 8).unwrap();
        let mut b = EsgEngine::new(baseline_dir("esg_ao"));
        b.set_adaptive_order(true);
        b.prepare(&e, N).unwrap();
        let hot = b.run(app.as_ref(), 8).unwrap();
        assert_eq!(base.values, hot.values, "esg {}", app.name());
        assert_eq!(base.io.bytes_read, hot.io.bytes_read, "esg bytes {}", app.name());

        // DSW (column order moves, block-row fold order does not)
        let mut a = DswEngine::new(baseline_dir("dsw_ao"));
        a.prepare(&e, N).unwrap();
        let base = a.run(app.as_ref(), 8).unwrap();
        let mut b = DswEngine::new(baseline_dir("dsw_ao"));
        b.set_adaptive_order(true);
        b.prepare(&e, N).unwrap();
        let hot = b.run(app.as_ref(), 8).unwrap();
        assert_eq!(base.values, hot.values, "dsw {}", app.name());
        assert_eq!(base.io.bytes_read, hot.io.bytes_read, "dsw bytes {}", app.name());

        // VSP
        let mut a = VspEngine::new(baseline_dir("vsp_ao"));
        a.prepare(&e, N).unwrap();
        let base = a.run(app.as_ref(), 8).unwrap();
        let mut b = VspEngine::new(baseline_dir("vsp_ao"));
        b.set_adaptive_order(true);
        b.prepare(&e, N).unwrap();
        let hot = b.run(app.as_ref(), 8).unwrap();
        assert_eq!(base.values, hot.values, "vsp {}", app.name());
        assert_eq!(base.io.bytes_read, hot.io.bytes_read, "vsp bytes {}", app.name());
    }
}
