//! Integration test: the full AOT round trip.
//!
//! `make artifacts` (python/jax/pallas) must have produced `artifacts/`;
//! this test loads the HLO text through PJRT and checks the kernels against
//! a native rust oracle on random shard-shaped inputs.
//!
//! Skipped (with a loud message) if `artifacts/` is absent so that plain
//! `cargo test` still passes before the first `make artifacts`.

use std::path::PathBuf;

use graphmp::runtime::ShardRuntime;
use graphmp::util::rng::Xoshiro256;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn native_segsum(contrib: &[f32], dst: &[u32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for (c, &d) in contrib.iter().zip(dst) {
        out[d as usize] += c;
    }
    out
}

fn native_segmin(contrib: &[f32], dst: &[u32], n: usize) -> Vec<f32> {
    let mut out = vec![f32::INFINITY; n];
    for (&c, &d) in contrib.iter().zip(dst) {
        out[d as usize] = out[d as usize].min(c);
    }
    out
}

#[test]
fn pjrt_kernels_match_native_oracle() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let rt = ShardRuntime::load(&dir).expect("load artifacts");
    let g = rt.geometry;
    let mut rng = Xoshiro256::seed_from_u64(12345);

    for trial in 0..3 {
        let n_vertices = [1usize, 100, g.v_max][trial];
        let n_edges = [1usize, 5_000, g.e_max][trial];
        let contrib: Vec<f32> = (0..n_edges).map(|_| rng.next_f32()).collect();
        let dst: Vec<u32> = (0..n_edges)
            .map(|_| rng.range_usize(0, n_vertices) as u32)
            .collect();

        // segsum
        let got = rt.segsum_shard(&contrib, &dst, n_vertices).unwrap();
        let want = native_segsum(&contrib, &dst, n_vertices);
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * b.abs().max(1.0),
                "segsum trial {trial} lane {i}: {a} vs {b}"
            );
        }

        // pr_shard = 0.15/N + 0.85*segsum
        let inv_n = 1.0 / 1000.0f32;
        let got = rt.pr_shard(&contrib, &dst, inv_n, n_vertices).unwrap();
        for (i, (a, s)) in got.iter().zip(&want).enumerate() {
            let b = 0.15 * inv_n + 0.85 * s;
            assert!(
                (a - b).abs() <= 1e-3 * b.abs().max(1.0),
                "pr trial {trial} lane {i}: {a} vs {b}"
            );
        }

        // relaxmin = min(old, segmin)
        let old: Vec<f32> = (0..n_vertices).map(|_| rng.next_f32() * 2.0).collect();
        let got = rt.relaxmin_shard(&contrib, &dst, &old, n_vertices).unwrap();
        let mins = native_segmin(&contrib, &dst, n_vertices);
        for i in 0..n_vertices {
            let b = old[i].min(mins[i]);
            assert!((got[i] - b).abs() <= 1e-6, "relaxmin trial {trial} lane {i}");
        }
    }
    assert!(rt.call_count() >= 9);
}
