//! Cross-engine equivalence + prefetch-pipeline determinism.
//!
//! 1. Property test: on random small R-MAT graphs, the VSW engine under the
//!    full configuration matrix (selective on/off × threads {1,2,4} ×
//!    prefetch_depth {0,2,4}) and every out-of-core baseline agree with the
//!    single-threaded in-memory reference for PageRank / SSSP / WCC.
//! 2. Regression: same graph, same seed — every (threads, prefetch_depth,
//!    adaptive) combination must produce **bit-identical** vertex arrays and
//!    identical per-iteration `shards_processed` / `shards_skipped`
//!    accounting.  This is the acceptance bar for the pipelined shard
//!    prefetcher *and* the adaptive I/O governor: overlapping I/O with
//!    compute — and re-sizing/re-ordering that overlap from run-time
//!    feedback — must be invisible in results, visible only in time.

use graphmp::apps::{PageRank, ProgramContext, Sssp, VertexProgram, Wcc};
use graphmp::baselines::{self, OocEngine};
use graphmp::engine::{EngineConfig, RunResult, VswEngine};
use graphmp::graph::generator;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;
use graphmp::util::prop;

const THREADS: [usize; 3] = [1, 2, 4];
const DEPTHS: [usize; 3] = [0, 2, 4];

/// Single-threaded in-memory reference (Algorithm 2 swept synchronously).
fn reference(
    app: &dyn VertexProgram,
    edges: &[(u32, u32)],
    n: usize,
    max_iters: usize,
) -> Vec<f32> {
    let ctx = ProgramContext { num_vertices: n as u64 };
    let mut in_adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut out_deg = vec![0u32; n];
    for &(s, d) in edges {
        in_adj[d as usize].push(s);
        out_deg[s as usize] += 1;
    }
    let mut vals: Vec<f32> = (0..n).map(|v| app.init(v as u32, &ctx)).collect();
    for _ in 0..max_iters {
        let next: Vec<f32> = (0..n)
            .map(|v| app.update(v as u32, &in_adj[v], &vals, &out_deg, &ctx))
            .collect();
        let changed = next
            .iter()
            .zip(&vals)
            .any(|(a, b)| !(a.is_infinite() && b.is_infinite()) && a != b);
        vals = next;
        if !changed {
            break;
        }
    }
    vals
}

fn build_dataset(tag: &str, edges: &[(u32, u32)], n: usize, shard_cap: usize) -> DatasetDir {
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("gmp_pfp_{tag}_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    preprocess(
        tag,
        edges,
        n,
        &dir,
        &PreprocessConfig { max_edges_per_shard: shard_cap, bloom_fpr: 0.01 },
    )
    .unwrap();
    dir
}

fn run_vsw(
    dir: &DatasetDir,
    app: &dyn VertexProgram,
    max_iters: usize,
    selective: bool,
    threads: usize,
    depth: usize,
) -> RunResult {
    run_vsw_gov(dir, app, max_iters, selective, threads, depth, false)
}

fn run_vsw_gov(
    dir: &DatasetDir,
    app: &dyn VertexProgram,
    max_iters: usize,
    selective: bool,
    threads: usize,
    depth: usize,
    adaptive: bool,
) -> RunResult {
    let engine = VswEngine::open(
        dir.clone(),
        EngineConfig {
            max_iters,
            threads,
            selective,
            // high threshold so SSSP/WCC tails actually exercise skipping
            selective_threshold: 0.05,
            prefetch_depth: depth,
            adaptive,
            ..Default::default()
        },
    )
    .unwrap();
    engine.run(app).unwrap()
}

fn assert_close(
    got: &[f32],
    want: &[f32],
    exact: bool,
    what: &str,
) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        if a.is_infinite() && b.is_infinite() {
            continue;
        }
        if exact {
            assert_eq!(a, b, "{what} v{i}: {a} vs {b}");
        } else {
            assert!(
                (a - b).abs() <= 1e-4 * b.abs().max(1e-6),
                "{what} v{i}: {a} vs {b}"
            );
        }
    }
}

/// The apps the paper evaluates, with (iteration cap, exact?) semantics:
/// PageRank compares at a fixed horizon with float tolerance, the
/// min-monoid apps run to their (unique) fixpoint and compare exactly.
fn app_matrix() -> Vec<(Box<dyn VertexProgram>, usize, usize, bool)> {
    vec![
        (Box::new(PageRank::default()), 6, 6, false),
        (Box::new(Sssp { source: 0 }), 400, 1000, true),
        (Box::new(Wcc), 400, 1000, true),
    ]
}

#[test]
fn vsw_config_matrix_and_baselines_match_reference() {
    prop::check(0xE911, 3, |g| {
        // a fresh random power-law multigraph per case, symmetrized so the
        // min-monoid apps have interesting reachable sets
        let scale = 7 + g.usize_in(0, 2) as u32; // 128 or 256 vertices
        let n = 1usize << scale;
        let m = g.usize_in(300, 900) as u64;
        let mut edges = generator::rmat(scale, m, generator::RmatParams::default(), g.u64());
        let rev: Vec<_> = edges.iter().map(|&(s, d)| (d, s)).collect();
        edges.extend(rev);
        let tag = format!("eq{}", g.case_seed);
        let dir = build_dataset(&tag, &edges, n, 256);

        for (app, engine_iters, ref_iters, exact) in app_matrix() {
            let want = reference(app.as_ref(), &edges, n, ref_iters);

            // VSW configuration matrix
            for selective in [false, true] {
                for &threads in &THREADS {
                    for &depth in &DEPTHS {
                        let got =
                            run_vsw(&dir, app.as_ref(), engine_iters, selective, threads, depth);
                        assert_close(
                            &got.values,
                            &want,
                            exact,
                            &format!(
                                "{} sel={selective} t={threads} d={depth}",
                                app.name()
                            ),
                        );
                    }
                }
            }

            // every out-of-core baseline + the in-memory engine
            for sys in ["psw", "esg", "dsw", "vsp", "inmem"] {
                let work = std::env::temp_dir()
                    .join(format!("gmp_pfp_base_{sys}_{}_{}", tag, std::process::id()));
                let mut eng = baselines::by_name(sys, work).unwrap();
                eng.prepare(&edges, n).unwrap();
                let run = eng.run(app.as_ref(), engine_iters).unwrap();
                assert_close(&run.values, &want, exact, &format!("{} {}", sys, app.name()));
            }
        }
        let _ = std::fs::remove_dir_all(&dir.root);
    });
}

#[test]
fn results_and_accounting_are_bit_identical_across_threads_and_depths() {
    // fixed graph, fixed seed: the determinism regression the prefetcher —
    // and, since PR 2, the adaptive I/O governor — must never break.  The
    // governor re-sizes the window and re-orders shard issue from run-time
    // measurements, so this is exactly where nondeterminism would leak in:
    // every `--adaptive` run must be bit-identical to every fixed one.
    let n = 1usize << 9;
    let edges = generator::rmat(9, 4000, generator::RmatParams::default(), 2024);
    let dir = build_dataset("det", &edges, n, 300);

    for (app, engine_iters, _, _) in app_matrix() {
        let mut golden: Option<(Vec<u32>, Vec<(usize, usize)>)> = None;
        for &threads in &THREADS {
            for &depth in &DEPTHS {
                for adaptive in [false, true] {
                    let got = run_vsw_gov(
                        &dir,
                        app.as_ref(),
                        engine_iters,
                        true,
                        threads,
                        depth,
                        adaptive,
                    );
                    let bits: Vec<u32> = got.values.iter().map(|v| v.to_bits()).collect();
                    let accounting: Vec<(usize, usize)> = got
                        .stats
                        .iters
                        .iter()
                        .map(|i| (i.shards_processed, i.shards_skipped))
                        .collect();
                    match &golden {
                        None => golden = Some((bits, accounting)),
                        Some((gb, ga)) => {
                            assert_eq!(
                                gb, &bits,
                                "{}: t={threads} d={depth} adaptive={adaptive} changed value bits",
                                app.name()
                            );
                            assert_eq!(
                                ga, &accounting,
                                "{}: t={threads} d={depth} adaptive={adaptive} changed shard accounting",
                                app.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn chunked_scheduler_and_compressed_gather_are_bit_identical() {
    // the intra-shard chunk scheduler hands pieces of one shard to many
    // workers, and the compressed-domain gather swaps the whole hit-path
    // representation — both must be invisible in results AND in the
    // per-iteration shard accounting, across codecs, chunk sizes, thread
    // counts and both prefetch paths
    use graphmp::cache::Codec;
    let n = 1usize << 9;
    let edges = generator::rmat(9, 4000, generator::RmatParams::default(), 2024);
    let dir = build_dataset("chunk", &edges, n, 300);

    for (app, engine_iters, _, _) in app_matrix() {
        for codec in [Codec::SnapLite, Codec::DeltaVarint, Codec::None] {
            // golden is per-codec: delta-varint legitimately normalizes
            // row order, which reorders float-Sum folds relative to the
            // byte codecs; *within* a codec every configuration must be
            // bit-identical
            let mut golden: Option<(Vec<u32>, Vec<(usize, usize)>)> = None;
            // chunk_rows 9 splits these ~35-row shards ~4 ways; 0 never
            // splits — the two scheduler extremes
            for stream in [true, false] {
                for &chunk_rows in &[0usize, 9] {
                    for &(threads, depth) in &[(4usize, 0usize), (4, 2)] {
                        let engine = VswEngine::open(
                            dir.clone(),
                            EngineConfig {
                                max_iters: engine_iters,
                                threads,
                                selective: true,
                                selective_threshold: 0.05,
                                prefetch_depth: depth,
                                cache_codec: codec,
                                stream_gather: stream,
                                chunk_rows,
                                ..Default::default()
                            },
                        )
                        .unwrap();
                        let got = engine.run(app.as_ref()).unwrap();
                        let bits: Vec<u32> =
                            got.values.iter().map(|v| v.to_bits()).collect();
                        let accounting: Vec<(usize, usize)> = got
                            .stats
                            .iters
                            .iter()
                            .map(|i| (i.shards_processed, i.shards_skipped))
                            .collect();
                        match &golden {
                            None => golden = Some((bits, accounting)),
                            Some((gb, ga)) => {
                                let what = format!(
                                    "{}: codec={} stream={stream} chunk_rows={chunk_rows} \
                                     t={threads} d={depth}",
                                    app.name(),
                                    codec.name()
                                );
                                assert_eq!(gb, &bits, "{what} changed value bits");
                                assert_eq!(ga, &accounting, "{what} changed accounting");
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn frontier_skipping_is_deterministic_under_prefetch() {
    // SSSP on a long path: selective scheduling skips most shards once the
    // frontier passes; skipped/processed counts must not depend on the
    // pipeline configuration, and skipping must actually happen
    let n = 400usize;
    let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|v| (v, v + 1)).collect();
    let dir = build_dataset("path", &edges, n, 32);
    let app = Sssp { source: 0 };

    let mut golden: Option<Vec<(usize, usize)>> = None;
    let mut golden_values: Option<Vec<u32>> = None;
    for &threads in &THREADS {
        for &(depth, adaptive) in &[(0usize, false), (2, false), (4, false), (2, true)] {
            let got = run_vsw_gov(&dir, &app, 0, true, threads, depth, adaptive);
            let accounting: Vec<(usize, usize)> = got
                .stats
                .iters
                .iter()
                .map(|i| (i.shards_processed, i.shards_skipped))
                .collect();
            let skipped: usize = accounting.iter().map(|(_, s)| s).sum();
            assert!(skipped > 0, "t={threads} d={depth}: no shards skipped");
            let bits: Vec<u32> = got.values.iter().map(|v| v.to_bits()).collect();
            match (&golden, &golden_values) {
                (None, _) => {
                    golden = Some(accounting);
                    golden_values = Some(bits);
                }
                (Some(ga), Some(gv)) => {
                    assert_eq!(ga, &accounting, "t={threads} d={depth} accounting");
                    assert_eq!(gv, &bits, "t={threads} d={depth} values");
                }
                _ => unreachable!(),
            }
        }
    }
}
