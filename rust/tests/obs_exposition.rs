//! Golden tests for the Prometheus text exposition.
//!
//! The registry renders its families in `DESCRIPTORS` order with a
//! `# HELP`/`# TYPE` header per family whether or not any series exist,
//! so the schema a scraper sees is a compile-time contract.  These tests
//! pin that contract: the exact `# TYPE` line sequence, known-value
//! series rendering (int counters, nanosecond counters as seconds, float
//! gauges, cumulative histogram buckets), and that every sample line the
//! renderer emits survives a trip through `parse_line` (what `graphmp
//! top` consumes).

use graphmp::obs::metrics as m;

/// Every metric family, in exposition order.  A new family lands here in
/// the same commit that adds its descriptor, or this test fails.
const GOLDEN_TYPES: &[(&str, &str)] = &[
    ("graphmp_io_read_bytes_total", "counter"),
    ("graphmp_io_written_bytes_total", "counter"),
    ("graphmp_io_read_ops_total", "counter"),
    ("graphmp_io_write_ops_total", "counter"),
    ("graphmp_io_throttle_stall_seconds_total", "counter"),
    ("graphmp_cache_hits_total", "counter"),
    ("graphmp_cache_misses_total", "counter"),
    ("graphmp_cache_evictions_total", "counter"),
    ("graphmp_cache_invalidations_total", "counter"),
    ("graphmp_cache_resident_bytes", "gauge"),
    ("graphmp_engine_iterations_total", "counter"),
    ("graphmp_engine_io_wait_seconds_total", "counter"),
    ("graphmp_engine_compute_seconds_total", "counter"),
    ("graphmp_engine_decode_seconds_total", "counter"),
    ("graphmp_engine_active_ratio", "gauge"),
    ("graphmp_engine_window", "gauge"),
    ("graphmp_engine_lent_bytes", "gauge"),
    ("graphmp_engine_epoch", "gauge"),
    ("graphmp_iter_seconds", "histogram"),
    ("graphmp_uring_direct_reads_total", "counter"),
    ("graphmp_uring_fallback_reads_total", "counter"),
    ("graphmp_uring_queue_depth", "gauge"),
    ("graphmp_sessions_open", "gauge"),
    ("graphmp_engines_resident", "gauge"),
    ("graphmp_engines_evicted_total", "counter"),
    ("graphmp_requests_total", "counter"),
    ("graphmp_admission_busy_total", "counter"),
    ("graphmp_jobs_inflight", "gauge"),
    ("graphmp_jobs_queued", "gauge"),
    ("graphmp_barrier_seconds", "histogram"),
    ("graphmp_barrier_delta_lines_total", "counter"),
    ("graphmp_part_stitch_bytes", "gauge"),
    ("graphmp_trace_records_total", "counter"),
    ("graphmp_trace_dropped_total", "counter"),
    ("graphmp_build_info", "gauge"),
];

#[test]
fn type_lines_render_in_descriptor_order() {
    m::set_enabled(true);
    let text = m::render();
    let got: Vec<&str> =
        text.lines().filter(|l| l.starts_with("# TYPE ")).collect();
    let want: Vec<String> = GOLDEN_TYPES
        .iter()
        .map(|(name, kind)| format!("# TYPE {name} {kind}"))
        .collect();
    assert_eq!(
        got, want,
        "exposed schema drifted — update GOLDEN_TYPES in the same commit as DESCRIPTORS"
    );
    // a scraper negotiates on this exact string
    assert_eq!(m::CONTENT_TYPE, "text/plain; version=0.0.4");
}

#[test]
fn known_values_render_exactly_and_reparse() {
    m::set_enabled(true);
    let l = &[("dataset", "golden.gmp")];
    m::counter_to("graphmp_cache_hits_total", l, 42);
    m::counter_add("graphmp_engine_io_wait_seconds_total", l, 1_500_000_000); // ns -> 1.5s
    m::gauge_set("graphmp_engine_window", l, 4);
    m::gauge_set_f64("graphmp_engine_active_ratio", l, 0.25);
    m::observe_secs("graphmp_iter_seconds", l, 0.003);
    m::observe_secs("graphmp_iter_seconds", l, 0.003);
    m::observe_secs("graphmp_iter_seconds", l, 1.0);

    let text = m::render();
    for want in [
        "graphmp_cache_hits_total{dataset=\"golden.gmp\"} 42",
        "graphmp_engine_io_wait_seconds_total{dataset=\"golden.gmp\"} 1.5",
        "graphmp_engine_window{dataset=\"golden.gmp\"} 4",
        "graphmp_engine_active_ratio{dataset=\"golden.gmp\"} 0.25",
        // 0.003 lands in le=0.005; buckets render cumulatively
        "graphmp_iter_seconds_bucket{dataset=\"golden.gmp\",le=\"0.005\"} 2",
        "graphmp_iter_seconds_bucket{dataset=\"golden.gmp\",le=\"2\"} 3",
        "graphmp_iter_seconds_bucket{dataset=\"golden.gmp\",le=\"+Inf\"} 3",
        "graphmp_iter_seconds_count{dataset=\"golden.gmp\"} 3",
    ] {
        assert!(
            text.lines().any(|line| line == want),
            "missing exact line {want:?} in:\n{text}"
        );
    }

    // every sample line the renderer emits must be machine-readable
    let mut samples = 0usize;
    for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let parsed = m::parse_line(line);
        assert!(parsed.is_some(), "unparseable exposition line: {line:?}");
        samples += 1;
    }
    assert!(samples > 0, "render produced no sample lines");

    // parse returns structured labels, not just strings
    let (name, labels, v) =
        m::parse_line("graphmp_iter_seconds_bucket{dataset=\"golden.gmp\",le=\"+Inf\"} 3")
            .unwrap();
    assert_eq!(name, "graphmp_iter_seconds_bucket");
    assert_eq!(labels.len(), 2);
    assert_eq!(labels[1], ("le".to_string(), "+Inf".to_string()));
    assert_eq!(v, 3.0);
}

#[test]
fn label_values_are_escaped_and_roundtrip() {
    m::set_enabled(true);
    let tricky = "we\"ird\\name";
    m::gauge_set("graphmp_cache_resident_bytes", &[("dataset", tricky)], 7);
    let text = m::render();
    let line = text
        .lines()
        .find(|l| l.starts_with("graphmp_cache_resident_bytes{") && l.contains("we\\\""))
        .unwrap_or_else(|| panic!("escaped series missing in:\n{text}"));
    let (_, labels, v) = m::parse_line(line).expect("escaped line must parse");
    assert_eq!(labels[0].1, tricky, "escape sequences must roundtrip");
    assert_eq!(v, 7.0);
}
