//! The resident-engine acceptance bar, at two levels.
//!
//! * **Library**: `run_pinned` against a snapshot taken before a burst of
//!   ingests must stay bit-identical to an engine opened with
//!   `epoch: Some(0)` — while the ingests and `refresh_latest` happen
//!   concurrently on other threads, against the *same* engine instance.
//! * **Black box**: a real `graphmp serve` daemon, driven through the
//!   `graphmp client` binary over TCP (and a bare Unix socket leg):
//!   sessions opened before an ingest keep reproducing their epoch's
//!   values byte-for-byte (`values=1` payload vs `run --dump-values`),
//!   new sessions see the new epoch, and `shutdown` actually exits.

use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use graphmp::apps::PageRank;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::generator;
use graphmp::graph::mutation::{self, Mutation};
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;

fn workdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gmp_srvsmoke_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ---- library level ------------------------------------------------------

#[test]
fn pinned_runs_stay_bit_exact_while_ingests_advance_concurrently() {
    let dir = DatasetDir::new(workdir("lib").join("data.gmp"));
    let edges = generator::erdos_renyi(128, 900, 77);
    let cfg = PreprocessConfig { max_edges_per_shard: 128, bloom_fpr: 0.01 };
    preprocess("srvlib", &edges, 128, &dir, &cfg).unwrap();

    let ecfg = EngineConfig { threads: 3, max_iters: 20, ..Default::default() };
    let engine = Arc::new(VswEngine::open(dir.clone(), ecfg.clone()).unwrap());
    let st0 = engine.snapshot();
    assert_eq!(st0.epoch, 0);

    // ground truth for epoch 0: a separate engine opened pinned to it
    let expect = {
        let pinned = VswEngine::open(dir.clone(), EngineConfig { epoch: Some(0), ..ecfg.clone() })
            .unwrap();
        bits(&pinned.run(&PageRank::default()).unwrap().values)
    };

    // two reader threads hammer the pre-ingest snapshot...
    let barrier = Arc::new(Barrier::new(3));
    let handles: Vec<_> = (0..2)
        .map(|_| {
            let (engine, st0, barrier, expect) =
                (engine.clone(), st0.clone(), barrier.clone(), expect.clone());
            std::thread::spawn(move || {
                barrier.wait();
                for _ in 0..2 {
                    let got = bits(&engine.run_pinned(&st0, &PageRank::default()).unwrap().values);
                    assert_eq!(got, expect, "pinned run drifted during concurrent ingest");
                }
            })
        })
        .collect();

    // ...while this thread advances the dataset underneath them, twice
    barrier.wait();
    for (i, batch) in [
        vec![
            Mutation::Insert { src: 0, dst: 100, weight: 1.0 },
            Mutation::Insert { src: 100, dst: 0, weight: 1.0 },
        ],
        vec![
            Mutation::Insert { src: 5, dst: 17, weight: 1.0 },
            Mutation::Delete { src: 0, dst: 100 },
        ],
    ]
    .iter()
    .enumerate()
    {
        mutation::ingest(&dir, batch, 0.01).unwrap();
        assert_eq!(engine.refresh_latest().unwrap(), i as u64 + 1);
    }
    let latest = bits(&engine.run(&PageRank::default()).unwrap().values);
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(engine.epoch(), 2);
    assert_ne!(latest, expect, "inserted edges must change pagerank at the new epoch");

    // the pre-ingest snapshot is still reproducible after the dust settles
    let again = bits(&engine.run_pinned(&st0, &PageRank::default()).unwrap().values);
    assert_eq!(again, expect);
    let _ = std::fs::remove_dir_all(dir.root.parent().unwrap());
}

// ---- black box ----------------------------------------------------------

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_graphmp"))
}

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().unwrap();
    assert!(
        out.status.success(),
        "command failed: {}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Extract `key=value` from a client `ok ...` header line.
fn kv(stdout: &str, key: &str) -> String {
    let prefix = format!("{key}=");
    stdout
        .split_whitespace()
        .find_map(|t| t.strip_prefix(&prefix))
        .unwrap_or_else(|| panic!("no {key}= in {stdout:?}"))
        .to_string()
}

/// Spawn `graphmp serve`, wait for its ready line, and keep the pipes
/// drained so the daemon can never block on a full pipe buffer.
fn spawn_daemon(extra: &[&str]) -> (Child, String) {
    let mut child = bin()
        .arg("serve")
        .args(["--listen", "127.0.0.1:0"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let mut stdout = BufReader::new(child.stdout.take().unwrap());
    let mut ready = String::new();
    stdout.read_line(&mut ready).unwrap();
    let addr = ready
        .trim()
        .strip_prefix("listening ")
        .unwrap_or_else(|| panic!("bad ready line {ready:?}"))
        .to_string();
    std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = stdout.read_to_string(&mut rest);
    });
    let mut stderr = child.stderr.take().unwrap();
    std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = stderr.read_to_string(&mut rest);
    });
    (child, addr)
}

fn wait_exit(child: &mut Child, what: &str) {
    let t0 = Instant::now();
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            assert!(status.success(), "{what}: daemon exited with {status}");
            return;
        }
        if t0.elapsed() > Duration::from_secs(60) {
            let _ = child.kill();
            panic!("{what}: daemon did not exit after shutdown");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn serve_daemon_pins_sessions_across_ingest_byte_for_byte() {
    let d = workdir("daemon");
    let edges = d.join("edges.bin");
    let data = d.join("data.gmp");
    run_ok(bin().args(["generate", "--dataset", "tiny", "--out"]).arg(&edges));
    run_ok(bin().args(["preprocess", "--input"]).arg(&edges).args(["--out"]).arg(&data));
    let data = data.display().to_string();

    let (mut child, addr) = spawn_daemon(&[]);
    let client = |tokens: &[&str], dump: Option<&Path>| -> String {
        let mut c = bin();
        c.args(["client", "--connect", &addr]);
        if let Some(p) = dump {
            c.arg("--dump-values").arg(p);
        }
        run_ok(c.args(tokens))
    };

    assert_eq!(kv(&client(&["ping"], None), "pong"), "1");

    // session 1 pins epoch 0; its payload matches `run --dump-values`
    let open1 = client(&["open", &format!("data={data}")], None);
    assert_eq!(kv(&open1, "epoch"), "0");
    let s1 = kv(&open1, "session");
    let srv0 = d.join("srv0.txt");
    let run1 = client(
        &["run", &format!("session={s1}"), "app=pagerank", "values=1"],
        Some(&srv0),
    );
    assert_eq!(kv(&run1, "epoch"), "0");
    let cli0 = d.join("cli0.txt");
    run_ok(
        bin()
            .args(["run", "--data", &data, "--app", "pagerank", "--dump-values"])
            .arg(&cli0),
    );
    assert_eq!(
        std::fs::read(&srv0).unwrap(),
        std::fs::read(&cli0).unwrap(),
        "serve payload must be byte-identical to run --dump-values"
    );

    // ingest through the daemon: the dataset moves to epoch 1...
    let batch = d.join("b.gmdl");
    run_ok(
        bin()
            .args(["mutate-gen", "--data", &data])
            .args(["--count", "40", "--seed", "9", "--delete-fraction", "0.25", "--out"])
            .arg(&batch),
    );
    let ing = client(
        &["ingest", &format!("data={data}"), &format!("batch={}", batch.display())],
        None,
    );
    assert_eq!(kv(&ing, "epoch"), "1");

    // ...but session 1 keeps reproducing epoch 0, byte for byte
    let srv0b = d.join("srv0b.txt");
    let run1b = client(
        &["run", &format!("session={s1}"), "app=pagerank", "values=1"],
        Some(&srv0b),
    );
    assert_eq!(kv(&run1b, "epoch"), "0");
    assert_eq!(
        std::fs::read(&srv0).unwrap(),
        std::fs::read(&srv0b).unwrap(),
        "pinned session drifted across an ingest"
    );

    // a fresh session sees epoch 1 and matches a fresh CLI run
    let open2 = client(&["open", &format!("data={data}")], None);
    assert_eq!(kv(&open2, "epoch"), "1");
    let s2 = kv(&open2, "session");
    let srv1 = d.join("srv1.txt");
    client(&["run", &format!("session={s2}"), "app=pagerank", "values=1"], Some(&srv1));
    let cli1 = d.join("cli1.txt");
    run_ok(
        bin()
            .args(["run", "--data", &data, "--app", "pagerank", "--dump-values"])
            .arg(&cli1),
    );
    assert_eq!(std::fs::read(&srv1).unwrap(), std::fs::read(&cli1).unwrap());
    assert_ne!(
        std::fs::read(&cli0).unwrap(),
        std::fs::read(&cli1).unwrap(),
        "the ingest must change pagerank"
    );

    // the old epoch stays reachable from the CLI too
    let cli0b = d.join("cli0b.txt");
    run_ok(
        bin()
            .args(["run", "--data", &data, "--app", "pagerank", "--epoch", "0", "--dump-values"])
            .arg(&cli0b),
    );
    assert_eq!(std::fs::read(&cli0).unwrap(), std::fs::read(&cli0b).unwrap());

    // light lookups echo the stored fixpoint bit-exactly
    let want = std::fs::read_to_string(&srv0).unwrap().lines().nth(5).unwrap().to_string();
    let val = client(
        &["value", &format!("session={s1}"), "app=pagerank", "vertex=5"],
        None,
    );
    assert_eq!(kv(&val, "value"), want);
    assert_eq!(kv(&client(&["stats"], None), "sessions"), "2");

    client(&["shutdown"], None);
    wait_exit(&mut child, "tcp daemon");
    let _ = std::fs::remove_dir_all(&d);
}

#[cfg(unix)]
#[test]
fn serve_answers_on_the_unix_socket_and_shuts_down() {
    let d = workdir("unix");
    let sock = d.join("graphmp.sock");
    let sock_s = sock.display().to_string();
    let (mut child, _addr) = spawn_daemon(&["--socket", &sock_s]);
    // the socket is bound before the ready line, but poll for the file to
    // stay robust against slow filesystems
    let t0 = Instant::now();
    while !sock.exists() && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(20));
    }
    let out = run_ok(bin().args(["client", "--socket", &sock_s, "ping"]));
    assert_eq!(kv(&out, "pong"), "1");
    run_ok(bin().args(["client", "--socket", &sock_s, "shutdown"]));
    wait_exit(&mut child, "unix daemon");
    let _ = std::fs::remove_dir_all(&d);
}
