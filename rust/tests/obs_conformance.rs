//! Observability must be *bit-invisible*: running with the metrics
//! registry hot and the flight recorder installed must produce values
//! byte-identical to a run with `GRAPHMP_OBS=0` — for the single-process
//! VSW engine and for a partitioned coordinator run alike.
//!
//! The enabled flag and the trace recorder are process-global, so every
//! test takes a shared gate and restores the enabled state before
//! releasing it.

use graphmp::apps;
use graphmp::cluster::{worker, Coordinator, PartitionManifest, StreamLink};
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::{generator, Edge, Weight};
use graphmp::obs::{metrics, trace};
use graphmp::sharding::{preprocess_weighted, PreprocessConfig};
use graphmp::storage::property::Property;
use graphmp::storage::DatasetDir;
use std::sync::Mutex;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

const N: usize = 128;
const APPS: [&str; 2] = ["pagerank", "weighted-sssp"];

fn build_dataset(tag: &str) -> DatasetDir {
    let mut edges: Vec<Edge> = generator::rmat(7, 600, generator::RmatParams::default(), 77);
    let rev: Vec<_> = edges.iter().map(|&(s, d)| (d, s)).collect();
    edges.extend(rev);
    let weights: Vec<Weight> = generator::synth_weights(&edges, 5);
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("gmp_obsconf_{tag}_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let cfg = PreprocessConfig { max_edges_per_shard: 128, bloom_fpr: 0.01 };
    preprocess_weighted(tag, &edges, &weights, N, &dir, &cfg).unwrap();
    dir
}

fn vsw_lines(dir: &DatasetDir, app_name: &str, cfg: &EngineConfig) -> Vec<String> {
    let engine = VswEngine::open(dir.clone(), cfg.clone()).unwrap();
    let app = apps::by_name(app_name).unwrap();
    let res = engine.run_any(&app).unwrap();
    (0..res.values.len()).map(|v| res.values.render_bits(v).unwrap()).collect()
}

fn partitioned_lines(dir: &DatasetDir, app_name: &str, cfg: &EngineConfig) -> Vec<String> {
    let p = Property::load(&dir.property_path()).unwrap().num_shards();
    let manifest = PartitionManifest::balanced(p, 2).unwrap();
    let mut links = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..manifest.num_parts() {
        let (stream, handle) = worker::spawn_local(dir.clone(), cfg.clone(), None).unwrap();
        links.push(StreamLink::new(stream));
        handles.push(handle);
    }
    let mut coord = Coordinator::new(manifest, links).unwrap();
    let summary = coord.run(app_name, cfg.max_iters, true).unwrap();
    drop(coord);
    for h in handles {
        h.join().unwrap().unwrap();
    }
    summary.values
}

fn assert_identical(a: &[String], b: &[String], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (v, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x, y, "{what}: vertex {v} diverged between obs-on and obs-off");
    }
}

#[test]
fn vsw_values_are_bit_identical_with_obs_on_and_off() {
    let _g = gate();
    let dir = build_dataset("vsw");
    let trace_path = dir.root.with_extension("gmtf");
    let cfg = EngineConfig { threads: 2, prefetch_depth: 2, ..Default::default() };
    for app in APPS {
        // obs fully hot: registry recording, flight recorder sampling
        // every shard
        metrics::set_enabled(true);
        trace::install(&trace_path, 256, 1).unwrap();
        let on = vsw_lines(&dir, app, &cfg);
        trace::finish().unwrap();
        assert!(
            !trace::read_records(&trace_path).unwrap().is_empty(),
            "the hot run must actually have recorded spans"
        );
        // the GRAPHMP_OBS=0 shape
        metrics::set_enabled(false);
        let off = vsw_lines(&dir, app, &cfg);
        metrics::set_enabled(true);
        assert_identical(&on, &off, &format!("vsw {app}"));
    }
    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_dir_all(&dir.root);
}

#[test]
fn partitioned_values_are_bit_identical_with_obs_on_and_off() {
    let _g = gate();
    let dir = build_dataset("part");
    let trace_path = dir.root.with_extension("gmtf");
    let cfg = EngineConfig { threads: 1, ..Default::default() };
    for app in APPS {
        metrics::set_enabled(true);
        trace::install(&trace_path, 256, 1).unwrap();
        let on = partitioned_lines(&dir, app, &cfg);
        trace::finish().unwrap();
        metrics::set_enabled(false);
        let off = partitioned_lines(&dir, app, &cfg);
        metrics::set_enabled(true);
        assert_identical(&on, &off, &format!("partrun {app}"));
    }
    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_dir_all(&dir.root);
}
