//! The dynamic-graph subsystem's acceptance bar.
//!
//! * **Delta ≡ rebuild, bit for bit** — a base dataset + N random
//!   insert/delete batches (+ optional compaction) must execute exactly
//!   like a from-scratch preprocess of the final edge list, on every value
//!   lane, weighted and unweighted, with selective scheduling, threading
//!   and prefetch all enabled.  This holds by construction (per-row edge
//!   order: base survivors in base order, then inserts in insertion order
//!   — the same sequence the stable counting sort produces) and is locked
//!   in here.
//! * **Incremental ≡ cold** — after insert-only batches, every monotone
//!   (Min/Max) app warm-started from the previous epoch's fixpoint with
//!   the inserted edges' sources as the active seed must land on the same
//!   fixpoint as a cold start, in no more iterations.
//!
//! Delta-varint is covered on the monotone lanes (min/max folds are
//! order-independent); on float-Sum lanes the dv codec's row-order
//! normalization composes differently with resident inserts than with a
//! rebuilt shard, so Sum equality is asserted on the order-preserving
//! codecs (None/SnapLite) — the same carve-out the cross-engine matrix
//! makes for ESG/DSW float-Sum reorders.

use graphmp::apps::{LabelProp, MaxDeg, PageRank, SpMv64, Sssp, VertexProgram, Wcc, WeightedSssp};
use graphmp::cache::Codec;
use graphmp::engine::{EngineConfig, VswEngine, WarmStart};
use graphmp::graph::generator;
use graphmp::graph::mutation::{self, Mutation};
use graphmp::runtime::EpochManifest;
use graphmp::sharding::{preprocess_weighted, PreprocessConfig};
use graphmp::storage::property::Property;
use graphmp::storage::DatasetDir;
use graphmp::util::prop;

fn tmpdir(tag: &str) -> DatasetDir {
    let d = std::env::temp_dir().join(format!("gmp_de_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    DatasetDir::new(d)
}

fn build(
    tag: &str,
    edges: &[(u32, u32)],
    weights: &[f32],
    n: usize,
    cap: usize,
) -> DatasetDir {
    let dir = tmpdir(tag);
    let cfg = PreprocessConfig { max_edges_per_shard: cap, bloom_fpr: 0.01 };
    preprocess_weighted(tag, edges, weights, n, &dir, &cfg).unwrap();
    dir
}

fn engine(dir: &DatasetDir, codec: Codec) -> VswEngine {
    VswEngine::open(
        dir.clone(),
        EngineConfig {
            threads: 3,
            // well past any test graph's diameter, so fixpoint apps truly
            // converge (warm-vs-cold equality needs real fixpoints)
            max_iters: 200,
            cache_codec: codec,
            prefetch_depth: 2,
            selective: true,
            selective_threshold: 0.05,
            ..Default::default()
        },
    )
    .unwrap()
}

fn bits_f32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits_f64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Run every lane on both engines and demand bit equality (`sum_lanes`
/// gates the float-Sum apps for codecs that reorder rows).
fn assert_engines_bit_identical(a: &VswEngine, b: &VswEngine, sum_lanes: bool, what: &str) {
    // Min lanes: f32 unweighted + weighted, u64, u32
    let x = a.run(&Sssp { source: 0 }).unwrap().values;
    let y = b.run(&Sssp { source: 0 }).unwrap().values;
    assert_eq!(bits_f32(&x), bits_f32(&y), "{what}: sssp");
    let x = a.run(&Wcc).unwrap().values;
    let y = b.run(&Wcc).unwrap().values;
    assert_eq!(bits_f32(&x), bits_f32(&y), "{what}: wcc");
    let x = a.run(&WeightedSssp { source: 0 }).unwrap().values;
    let y = b.run(&WeightedSssp { source: 0 }).unwrap().values;
    assert_eq!(bits_f32(&x), bits_f32(&y), "{what}: wsssp");
    let lp: &dyn VertexProgram<u64> = &LabelProp;
    assert_eq!(a.run(lp).unwrap().values, b.run(lp).unwrap().values, "{what}: labelprop");
    let md: &dyn VertexProgram<u32> = &MaxDeg;
    assert_eq!(a.run(md).unwrap().values, b.run(md).unwrap().values, "{what}: maxdeg");
    if sum_lanes {
        let x = a.run(&PageRank::default()).unwrap().values;
        let y = b.run(&PageRank::default()).unwrap().values;
        assert_eq!(bits_f32(&x), bits_f32(&y), "{what}: pagerank");
        let sp: &dyn VertexProgram<f64> = &SpMv64::default();
        let x = a.run(sp).unwrap().values;
        let y = b.run(sp).unwrap().values;
        assert_eq!(bits_f64(&x), bits_f64(&y), "{what}: spmv64");
    }
}

#[test]
fn prop_delta_merged_and_compacted_execution_equal_from_scratch_rebuild() {
    prop::check(0xDE17A, 6, |g| {
        let n = g.usize_in(24, 120);
        let m = g.usize_in(20, 400);
        let base_edges = g.edges(n, m);
        let weighted = g.bool(0.5);
        let base_weights: Vec<f32> = if weighted {
            (0..m).map(|_| (g.usize_in(1, 9) as f32) * 0.25).collect()
        } else {
            Vec::new()
        };
        let cap = g.usize_in(16, 128);
        let tag = format!("p{}", g.case_seed);
        let dir = build(&tag, &base_edges, &base_weights, n, cap);

        // N random batches, deletes aimed at live edges
        let mut final_edges = base_edges.clone();
        let mut final_weights = base_weights.clone();
        let num_batches = g.usize_in(1, 4);
        for b in 0..num_batches {
            let count = g.usize_in(1, 40);
            let batch = mutation::synth_batch(
                n,
                &final_edges,
                count,
                0.35,
                weighted,
                g.case_seed ^ (b as u64 + 1),
            );
            mutation::apply_batch(&mut final_edges, &mut final_weights, &batch).unwrap();
            mutation::ingest(&dir, &batch, 0.01).unwrap();
        }
        // optional (possibly partial) compaction
        if g.bool(0.5) {
            let ratio = if g.bool(0.5) { 0.0 } else { 0.3 };
            mutation::compact(&dir, ratio).unwrap();
        }

        // from-scratch preprocess of the final edge list
        let rebuilt = build(&format!("{tag}_rb"), &final_edges, &final_weights, n, cap);

        // order-preserving codecs: every lane must match bit for bit
        for codec in [Codec::None, Codec::SnapLite] {
            let a = engine(&dir, codec);
            let b = engine(&rebuilt, codec);
            assert_engines_bit_identical(&a, &b, true, &format!("codec {}", codec.name()));
        }
        // delta-varint: monotone lanes (order-independent folds)
        let a = engine(&dir, Codec::DeltaVarint);
        let b = engine(&rebuilt, Codec::DeltaVarint);
        assert_engines_bit_identical(&a, &b, false, "codec delta-varint");

        let _ = std::fs::remove_dir_all(&dir.root);
        let _ = std::fs::remove_dir_all(&rebuilt.root);
    });
}

#[test]
fn prop_incremental_restart_equals_cold_start_on_monotone_apps() {
    prop::check(0x1C4E, 6, |g| {
        let n = g.usize_in(32, 160);
        let m = g.usize_in(30, 500);
        let base_edges = g.edges(n, m);
        let weighted = g.bool(0.5);
        let base_weights: Vec<f32> = if weighted {
            (0..m).map(|_| (g.usize_in(1, 9) as f32) * 0.25).collect()
        } else {
            Vec::new()
        };
        let tag = format!("w{}", g.case_seed);
        let dir = build(&tag, &base_edges, &base_weights, n, 64);

        // fixpoints at the base epoch
        let e0 = engine(&dir, Codec::SnapLite);
        let sssp0 = e0.run(&Sssp { source: 0 }).unwrap();
        let wcc0 = e0.run(&Wcc).unwrap();
        let wsssp0 = e0.run(&WeightedSssp { source: 0 }).unwrap();
        let lp: &dyn VertexProgram<u64> = &LabelProp;
        let lp0 = e0.run(lp).unwrap();
        let md: &dyn VertexProgram<u32> = &MaxDeg;
        let md0 = e0.run(md).unwrap();
        drop(e0);

        // insert-only history across a couple of epochs
        for b in 0..g.usize_in(1, 3) {
            let batch = mutation::synth_batch(
                n,
                &[],
                g.usize_in(1, 30),
                0.0,
                weighted,
                g.case_seed ^ (0x100 + b as u64),
            );
            assert!(batch.iter().all(|mu| mu.is_insert()));
            mutation::ingest(&dir, &batch, 0.01).unwrap();
        }

        let e1 = engine(&dir, Codec::SnapLite);
        let property = Property::load(&dir.property_path()).unwrap();
        let manifest = EpochManifest::load_or_bootstrap(&dir, &property).unwrap();
        let plan = mutation::incremental_plan(&dir, &manifest, 0, e1.epoch())
            .unwrap()
            .expect("insert-only history is always eligible");
        assert!(!plan.has_resets(), "insert-only history must not require resets");
        let seed = plan.seed;

        // every monotone lane: warm == cold, in no more iterations
        macro_rules! check_warm {
            ($app:expr, $fix:expr, $label:literal) => {{
                let cold = e1.run($app).unwrap();
                let warm = e1
                    .run_seeded(
                        $app,
                        Some(WarmStart { values: $fix.values.clone(), active: seed.clone() }),
                    )
                    .unwrap();
                assert_eq!(warm.values, cold.values, concat!($label, ": warm != cold"));
                assert!(
                    warm.stats.num_iters() <= cold.stats.num_iters(),
                    concat!($label, ": warm iterated more than cold")
                );
            }};
        }
        check_warm!(&Sssp { source: 0 }, sssp0, "sssp");
        check_warm!(&Wcc, wcc0, "wcc");
        check_warm!(&WeightedSssp { source: 0 }, wsssp0, "wsssp");
        check_warm!(lp, lp0, "labelprop");
        check_warm!(md, md0, "maxdeg");

        let _ = std::fs::remove_dir_all(&dir.root);
    });
}

#[test]
fn deletions_warm_start_via_reset_plan_and_match_cold() {
    // deleting an edge can *raise* Min-lattice values: the plan must carry
    // a reset set (the forward closure of the cut) and warm restart through
    // it must land exactly where a cold run — and a rebuild — lands
    let n = 64;
    let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|v| (v, v + 1)).collect();
    let dir = build("delpath", &edges, &[], n, 32);
    let e0 = engine(&dir, Codec::SnapLite);
    let before = e0.run(&Sssp { source: 0 }).unwrap();
    assert_eq!(before.values[n - 1], (n - 1) as f32);
    drop(e0);

    // cut the path in the middle
    let batch = vec![Mutation::Delete { src: 31, dst: 32 }];
    mutation::ingest(&dir, &batch, 0.01).unwrap();
    let property = Property::load(&dir.property_path()).unwrap();
    let manifest = EpochManifest::load_or_bootstrap(&dir, &property).unwrap();
    let plan = mutation::incremental_plan(&dir, &manifest, 0, 1)
        .unwrap()
        .expect("a delete-bearing range targeting the current epoch is plannable");
    // everything downstream of the cut gets re-derived
    let expect: Vec<u32> = (32..n as u32).collect();
    assert_eq!(plan.reset, expect, "reset = forward closure of the deleted edge's dst");
    assert!(plan.seed.iter().all(|v| (32..n as u32).contains(v)));

    let e1 = engine(&dir, Codec::SnapLite);
    let cold = e1.run(&Sssp { source: 0 }).unwrap();
    assert!(cold.values[40].is_infinite(), "the far side must become unreachable");
    assert_eq!(cold.values[31], 31.0, "the near side keeps its distances");

    let app = graphmp::apps::AnyProgram::F32(Box::new(Sssp { source: 0 }));
    let warm = e1
        .run_any_plan(&app, before.values.clone().into(), &plan)
        .unwrap();
    let graphmp::graph::AnyValues::F32(warm_values) = &warm.values else {
        panic!("sssp runs on the f32 lane");
    };
    assert_eq!(bits_f32(warm_values), bits_f32(&cold.values), "warm-via-plan != cold");
    assert!(
        warm.stats.num_iters() <= cold.stats.num_iters(),
        "delete-capable warm restart iterated more than cold"
    );

    let mut final_edges = edges.clone();
    let mut w = Vec::new();
    mutation::apply_batch(&mut final_edges, &mut w, &batch).unwrap();
    let rebuilt = build("delpath_rb", &final_edges, &[], n, 32);
    let want = engine(&rebuilt, Codec::SnapLite).run(&Sssp { source: 0 }).unwrap();
    assert_eq!(bits_f32(&cold.values), bits_f32(&want.values));
}

#[test]
fn historical_epochs_stay_reproducible_after_mutations_and_compaction() {
    let edges = generator::erdos_renyi(96, 600, 77);
    let dir = build("hist", &edges, &[], 96, 64);
    let base = engine(&dir, Codec::SnapLite).run(&Wcc).unwrap();

    let b1 = mutation::synth_batch(96, &edges, 50, 0.3, false, 5);
    mutation::ingest(&dir, &b1, 0.01).unwrap();
    let at1 = engine(&dir, Codec::SnapLite).run(&Wcc).unwrap();
    let b2 = mutation::synth_batch(96, &[], 30, 0.0, false, 6);
    mutation::ingest(&dir, &b2, 0.01).unwrap();
    mutation::compact(&dir, 0.0).unwrap();

    // pinned readers reproduce every historical epoch bit-for-bit
    let open_at = |e: u64| {
        VswEngine::open(
            dir.clone(),
            EngineConfig { epoch: Some(e), max_iters: 200, threads: 2, ..Default::default() },
        )
        .unwrap()
    };
    assert_eq!(bits_f32(&open_at(0).run(&Wcc).unwrap().values), bits_f32(&base.values));
    assert_eq!(bits_f32(&open_at(1).run(&Wcc).unwrap().values), bits_f32(&at1.values));
    // the compacted epoch equals the pre-compaction epoch it merged
    let at2 = open_at(2).run(&Wcc).unwrap();
    let at3 = open_at(3).run(&Wcc).unwrap();
    assert_eq!(bits_f32(&at2.values), bits_f32(&at3.values));
}
