//! Governor convergence: the adaptive window must *move the right way*
//! under artificial I/O conditions, without ever changing results.
//!
//! Two runs of the same PageRank workload, same graph, same seed:
//!
//! * **slow I/O** — cache disabled, the global byte throttle engaged, so
//!   every iteration re-reads every shard at HDD-ish speed.  Workers stall
//!   on acquisition, the io-wait fraction saturates, and the governor must
//!   grow the read-ahead window.
//! * **instant I/O** — mode-1 cache (decoded `Arc`s, allocation-free hits)
//!   warmed at open, no throttle.  Acquisition is a pointer clone, compute
//!   dominates, and the governor must not grow (and should shrink) the
//!   window.
//!
//! The slow run must end with a strictly larger window than the instant
//! run, and both value arrays must match the in-memory reference — the
//! feedback loop may only change *when bytes move*, never what is computed.
//!
//! Kept to a single `#[test]` because the I/O throttle is process-global.

use graphmp::apps::{PageRank, ProgramContext, VertexProgram};
use graphmp::cache::Codec;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::generator;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::{io, DatasetDir};

/// Single-threaded in-memory reference.
fn reference(
    app: &dyn VertexProgram,
    edges: &[(u32, u32)],
    n: usize,
    max_iters: usize,
) -> Vec<f32> {
    let ctx = ProgramContext { num_vertices: n as u64 };
    let mut in_adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut out_deg = vec![0u32; n];
    for &(s, d) in edges {
        in_adj[d as usize].push(s);
        out_deg[s as usize] += 1;
    }
    let mut vals: Vec<f32> = (0..n).map(|v| app.init(v as u32, &ctx)).collect();
    for _ in 0..max_iters {
        vals = (0..n)
            .map(|v| app.update(v as u32, &in_adj[v], &vals, &out_deg, &ctx))
            .collect();
    }
    vals
}

fn assert_matches(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * b.abs().max(1e-6),
            "{what} v{i}: {a} vs {b}"
        );
    }
}

/// Clears the global throttle even if an assertion fires mid-test.
struct ThrottleOff;
impl Drop for ThrottleOff {
    fn drop(&mut self) {
        io::set_throttle(0);
    }
}

#[test]
fn window_grows_under_slow_io_shrinks_under_instant_io_and_matches_reference() {
    let _guard = ThrottleOff;
    let n = 1usize << 11; // 2048 vertices
    let edges = generator::rmat(11, 400_000, generator::RmatParams::default(), 77);
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("gmp_gov_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    // ~50 shards of ~8K edges: enough shards for the window to matter, and
    // several milliseconds of compute per iteration, so pipeline-startup
    // noise on a loaded CI runner cannot masquerade as an I/O stall in the
    // instant-I/O run
    preprocess(
        "gov",
        &edges,
        n,
        &dir,
        &PreprocessConfig { max_edges_per_shard: 8192, bloom_fpr: 0.01 },
    )
    .unwrap();

    let iters = 8;
    let want = reference(&PageRank::default(), &edges, n, iters);
    let base_cfg = EngineConfig {
        max_iters: iters,
        threads: 4,
        selective: false,
        adaptive: true,
        prefetch_depth: 2, // both runs start from the same window
        prefetch_max: 8,
        ..Default::default()
    };

    // -- slow I/O: no cache, throttled disk => io-bound => window grows ---
    io::set_throttle(32 << 20); // 32 MiB/s
    let slow_engine = VswEngine::open(
        dir.clone(),
        EngineConfig { cache_budget: 0, ..base_cfg.clone() },
    )
    .unwrap();
    let slow = slow_engine.run(&PageRank::default()).unwrap();
    io::set_throttle(0);

    // -- instant I/O: warmed mode-1 cache (allocation-free hits) =>
    // compute-bound => window must not grow ----------------------------
    let fast_engine = VswEngine::open(
        dir.clone(),
        EngineConfig { cache_codec: Codec::None, ..base_cfg },
    )
    .unwrap();
    let fast = fast_engine.run(&PageRank::default()).unwrap();

    let slow_final = slow.stats.final_prefetch_depth();
    let fast_final = fast.stats.final_prefetch_depth();
    assert!(
        slow_final > fast_final,
        "slow-I/O window ({slow_final}) must end above instant-I/O window ({fast_final});\n\
         slow trajectory: {:?}\nfast trajectory: {:?}",
        slow.stats.iters.iter().map(|i| i.prefetch_depth).collect::<Vec<_>>(),
        fast.stats.iters.iter().map(|i| i.prefetch_depth).collect::<Vec<_>>(),
    );
    assert!(
        slow_final >= 4,
        "throttled disk never grew the window past {slow_final}"
    );
    assert!(
        fast.stats.io_wait_fraction() < slow.stats.io_wait_fraction(),
        "warmed cache should wait less than throttled disk"
    );
    // the memory estimate must account the high-water window
    assert!(slow_engine.governor().high_water() >= slow.stats.max_prefetch_depth());

    // adaptation may only change *when bytes move*, never the results
    assert_matches(&slow.values, &want, "slow/adaptive");
    assert_matches(&fast.values, &want, "fast/adaptive");
    assert_eq!(
        slow.values
            .iter()
            .map(|v| v.to_bits())
            .collect::<Vec<_>>(),
        fast.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "adaptive runs under different I/O speeds must stay bit-identical"
    );

    let _ = std::fs::remove_dir_all(&dir.root);
}
