//! Standing-query acceptance bar (`graphmp watch` / `standing`).
//!
//! * **Changed-set ≡ dump diff** — across R random mutation batches
//!   (delete-bearing included), every lane's watch emission must equal the
//!   line-by-line diff of two full `--dump-values` renderings: exactly the
//!   vertices whose bit-exact text changed, each as `<vertex> <bits>`.
//!   Monotone lanes advance warm (delete batches via reset plans),
//!   single-pass Sum refolds only mutated rows, iterative Sum recomputes
//!   cold — the emission contract is identical for all of them.
//! * **Stale fixpoints never warm-start** — a fixpoint saved at epoch N
//!   must not seed a run targeting an epoch `< N` (the mutation range
//!   would read as empty and silently keep future values); it degrades to
//!   a cold start instead.
//! * **Sliding windows expire as mutation stream** — with `--window N`,
//!   aging out the oldest ingest batch replays its inserts as deletes, and
//!   the advanced values equal a cold run over the surviving window.

use graphmp::apps;
use graphmp::cache::Codec;
use graphmp::engine::standing::{self, AdvanceMode};
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::mutation::{self, Mutation};
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::{delta, DatasetDir};
use graphmp::util::prop;

fn tmpdir(tag: &str) -> DatasetDir {
    let d = std::env::temp_dir().join(format!("gmp_watch_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    DatasetDir::new(d)
}

fn build(tag: &str, edges: &[(u32, u32)], n: usize) -> DatasetDir {
    let dir = tmpdir(tag);
    let cfg = PreprocessConfig { max_edges_per_shard: 64, bloom_fpr: 0.01 };
    preprocess(tag, edges, n, &dir, &cfg).unwrap();
    dir
}

/// Fresh engine per advance, the way the CLI one-shot opens one.
/// `max_iters` 200 for fixpoint apps; 0 (= app default) for single-pass.
fn engine(dir: &DatasetDir, max_iters: usize) -> VswEngine {
    VswEngine::open(
        dir.clone(),
        EngineConfig {
            threads: 2,
            max_iters,
            cache_codec: Codec::SnapLite,
            selective: true,
            selective_threshold: 0.05,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Full bit-exact dump, split into per-vertex lines (no vertex prefix).
fn dump(dir: &DatasetDir, name: &str, max_iters: usize) -> Vec<String> {
    let app = apps::by_name(name).unwrap();
    let e = engine(dir, max_iters);
    let r = e.run_any(&app).unwrap();
    (0..r.values.len()).map(|i| r.values.render_bits(i).unwrap()).collect()
}

/// The expected emission: `<vertex> <bits>` for every line that differs.
fn dump_diff(old: &[String], new: &[String]) -> Vec<String> {
    assert_eq!(old.len(), new.len());
    old.iter()
        .zip(new)
        .enumerate()
        .filter(|(_, (a, b))| a != b)
        .map(|(v, (_, b))| format!("{v} {b}"))
        .collect()
}

#[test]
fn prop_watch_changed_set_equals_dump_diff_across_random_batches() {
    // (app, engine max_iters, modes an advance may legally report)
    const LANES: &[(&str, usize, &[AdvanceMode])] = &[
        ("sssp", 200, &[AdvanceMode::Warm, AdvanceMode::WarmReset]),
        ("maxdeg", 200, &[AdvanceMode::Warm, AdvanceMode::WarmReset]),
        ("spmv", 0, &[AdvanceMode::Rows]),
        ("pagerank", 0, &[AdvanceMode::Cold]),
    ];
    prop::check(0x5A7C, 4, |g| {
        let n = g.usize_in(48, 128);
        let m = g.usize_in(60, 400);
        let mut edges = g.edges(n, m);
        let mut weights: Vec<f32> = Vec::new();
        let tag = format!("ws{}", g.case_seed);
        let dir = build(&tag, &edges, n);

        // register every lane: full emission of n lines
        let mut dumps: Vec<Vec<String>> = Vec::new();
        for &(name, iters, _) in LANES {
            let app = apps::by_name(name).unwrap();
            let e = engine(&dir, iters);
            let out = standing::watch_advance(&dir, &e, &app, None).unwrap();
            assert!(out.registered, "{name}: first call must register");
            assert_eq!(out.lines.len(), n, "{name}: registration emits every vertex");
            let full = dump(&dir, name, iters);
            let all: Vec<String> =
                full.iter().enumerate().map(|(v, b)| format!("{v} {b}")).collect();
            assert_eq!(out.lines, all, "{name}: registration emission != full dump");
            dumps.push(full);
        }

        // R delete-bearing batches; each advance must emit the dump diff
        let rounds = g.usize_in(2, 4);
        for r in 0..rounds {
            let batch = mutation::synth_batch(
                n,
                &edges,
                g.usize_in(5, 25),
                0.4,
                false,
                g.case_seed ^ (0xB00 + r as u64),
            );
            mutation::apply_batch(&mut edges, &mut weights, &batch).unwrap();
            mutation::ingest(&dir, &batch, 0.01).unwrap();
            let has_delete = batch.iter().any(|mu| !mu.is_insert());

            for (i, &(name, iters, modes)) in LANES.iter().enumerate() {
                let app = apps::by_name(name).unwrap();
                let e = engine(&dir, iters);
                let out = standing::watch_advance(&dir, &e, &app, None).unwrap();
                assert!(!out.registered);
                assert!(
                    modes.contains(&out.mode),
                    "{name}: unexpected advance mode {:?} (delete={has_delete})",
                    out.mode
                );
                let new = dump(&dir, name, iters);
                assert_eq!(
                    out.lines,
                    dump_diff(&dumps[i], &new),
                    "{name}: round {r} emission != dump diff (delete={has_delete})"
                );
                dumps[i] = new;
            }
        }

        let _ = std::fs::remove_dir_all(&dir.root);
    });
}

#[test]
fn incremental_rejects_fixpoint_saved_ahead_of_run_epoch() {
    let n = 64;
    let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|v| (v, v + 1)).collect();
    let dir = build("stale", &edges, n);
    let app = apps::by_name("sssp").unwrap();

    // two insert-only epochs
    for s in 0..2u64 {
        let batch = vec![Mutation::Insert { src: 0, dst: 40 + s as u32, weight: 1.0 }];
        mutation::ingest(&dir, &batch, 0.01).unwrap();
    }

    // fixpoint saved at the latest epoch (2)
    let e2 = engine(&dir, 200);
    assert_eq!(e2.epoch(), 2);
    let fix = e2.run_any(&app).unwrap();
    delta::save_values(&dir.values_path(app.name()), e2.epoch(), &fix.values).unwrap();
    drop(e2);

    // a run pinned at epoch 1 must NOT warm-start from the epoch-2 save:
    // the mutation range (2, 1] is empty and warm restart would silently
    // keep future values.  It must fall back cold — and match a cold run.
    let pinned = VswEngine::open(
        dir.clone(),
        EngineConfig { epoch: Some(1), threads: 2, max_iters: 200, ..Default::default() },
    )
    .unwrap();
    assert_eq!(pinned.epoch(), 1);
    let adv = standing::incremental_run(&dir, &pinned, &app).unwrap();
    assert_eq!(adv.mode, AdvanceMode::Cold, "stale-ahead fixpoint must run cold");
    let cold = pinned.run_any(&app).unwrap();
    assert_eq!(adv.result.values, cold.values, "cold fallback diverged");

    // sanity: the same save warm-starts a run that targets a *later* epoch
    let batch = vec![Mutation::Insert { src: 0, dst: 50, weight: 1.0 }];
    mutation::ingest(&dir, &batch, 0.01).unwrap();
    let e3 = engine(&dir, 200);
    assert_eq!(e3.epoch(), 3);
    let adv2 = standing::incremental_run(&dir, &e3, &app).unwrap();
    assert_eq!(adv2.mode, AdvanceMode::Warm);
    assert_eq!(adv2.result.values, e3.run_any(&app).unwrap().values);

    let _ = std::fs::remove_dir_all(&dir.root);
}

#[test]
fn sliding_window_expires_oldest_batch_as_deletes() {
    let n = 16;
    let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|v| (v, v + 1)).collect();
    let dir = build("window", &edges, n);
    let app = apps::by_name("sssp").unwrap();

    // register with a one-batch window: dist v = v on the bare path
    let e = engine(&dir, 200);
    let out = standing::watch_advance(&dir, &e, &app, Some(1)).unwrap();
    assert!(out.registered);
    drop(e);

    // batch A: shortcut 0 -> 8 (dist 8 drops to 1, downstream follows)
    mutation::ingest(&dir, &[Mutation::Insert { src: 0, dst: 8, weight: 1.0 }], 0.01).unwrap();
    let e = engine(&dir, 200);
    let out = standing::watch_advance(&dir, &e, &app, None).unwrap();
    assert_eq!(out.expired, 0, "window of 1 holds the single live batch");
    assert!(out.lines.iter().any(|l| l.starts_with("8 ")), "dist[8] must change");
    drop(e);

    // batch B: shortcut 0 -> 12; the window is full, so batch A expires —
    // its insert is replayed as a delete and dist[8] returns to 8
    mutation::ingest(&dir, &[Mutation::Insert { src: 0, dst: 12, weight: 1.0 }], 0.01).unwrap();
    let e = engine(&dir, 200);
    let out = standing::watch_advance(&dir, &e, &app, None).unwrap();
    assert_eq!(out.expired, 1, "the oldest batch must age out");

    // the advanced values equal a cold run over the surviving graph
    // (base path + shortcut 0->12 only)
    let cold = e.run_any(&app).unwrap();
    let state = delta::load_watch(&dir.watch_path(app.name())).unwrap();
    assert_eq!(state.values, cold.values, "window advance != cold over surviving window");
    let want: Vec<(u32, u32)> = edges.iter().copied().chain([(0, 12)]).collect();
    let rebuilt = build("window_rb", &want, n);
    let wantv = engine(&rebuilt, 200).run_any(&app).unwrap();
    assert_eq!(state.values, wantv.values, "surviving window != rebuilt graph");

    let _ = std::fs::remove_dir_all(&dir.root);
    let _ = std::fs::remove_dir_all(&rebuilt.root);
}
