//! Direct-I/O equivalence: the `O_DIRECT` submission ring must be an
//! invisible substitution for buffered reads.
//!
//! Two layers:
//! * **Byte layer** — [`DirectShardReader`] vs `std::fs::read`,
//!   byte-for-byte, across file sizes chosen to hit every alignment edge
//!   (sub-sector files, exact sector/segment multiples, unaligned tails
//!   that force the short-read restart path), in both the resolved mode
//!   and the forced thread-pool fallback.
//! * **Engine layer** — full runs over every cache codec with
//!   `--direct-io` on and off produce bit-identical fixpoints, both with
//!   a warm cache (direct reads only during load) and with the cache off
//!   (every iteration is cold reads).

use graphmp::apps::{PageRank, Sssp};
use graphmp::cache::Codec;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::generator;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::uring::{DirectShardReader, RingMode};
use graphmp::storage::DatasetDir;
use graphmp::util::rng::Xoshiro256;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("gmp_directio_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn reader_matches_buffered_byte_for_byte() {
    const SEG: usize = 1 << 20; // uring's submission segment
    let dir = tmp_dir("bytes");
    // alignment edges: sub-sector, sector±1, segment±1, multi-segment
    // with a ragged tail, and an empty file
    let sizes = [
        0usize,
        1,
        511,
        4095,
        4096,
        4097,
        SEG - 1,
        SEG,
        SEG + 1,
        2 * SEG + 4096 + 7,
        3 * SEG + 513,
    ];
    let readers = [
        ("resolved", DirectShardReader::with_mode(graphmp::storage::uring::resolve_mode(), 4)),
        ("pool", DirectShardReader::with_mode(RingMode::Pool, 3)),
    ];
    let mut rng = Xoshiro256::seed_from_u64(0xD1EC7);
    for (i, &size) in sizes.iter().enumerate() {
        let mut data = vec![0u8; size];
        for b in data.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        let path = dir.join(format!("f{i}.bin"));
        std::fs::write(&path, &data).unwrap();
        let want = std::fs::read(&path).unwrap();
        for (label, reader) in &readers {
            let got = reader.read_file(&path).unwrap();
            assert_eq!(got, want, "{label} reader diverged at size {size}");
        }
    }
    for (label, reader) in &readers {
        let (d, f) = reader.counts();
        assert_eq!(
            (d + f) as usize,
            sizes.len(),
            "{label} reader must count one read per file"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reader_surfaces_missing_files_as_errors() {
    let reader = DirectShardReader::with_mode(RingMode::Pool, 2);
    assert!(reader.read_file(std::path::Path::new("/nonexistent/gmp_shard")).is_err());
}

fn build_dataset(tag: &str) -> DatasetDir {
    let dir = DatasetDir::new(tmp_dir(tag).join("data"));
    let edges = generator::rmat(8, 3000, generator::RmatParams::default(), 7);
    let cfg = PreprocessConfig { max_edges_per_shard: 200, bloom_fpr: 0.01 };
    preprocess(tag, &edges, 256, &dir, &cfg).unwrap();
    dir
}

fn run_pagerank(dir: &DatasetDir, codec: Codec, budget: usize, direct_io: bool) -> Vec<u32> {
    let engine = VswEngine::open(
        dir.clone(),
        EngineConfig {
            max_iters: 4,
            threads: 3,
            selective: false,
            cache_codec: codec,
            cache_budget: budget,
            direct_io,
            ..Default::default()
        },
    )
    .unwrap();
    let result = engine.run(&PageRank::default()).unwrap();
    if direct_io {
        let (d, f) = engine.direct_reader().expect("reader must exist").counts();
        assert!(d + f > 0, "direct_io run never touched the ring");
    }
    result.values.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn engine_fixpoints_are_bit_identical_across_codecs_and_io_paths() {
    let dir = build_dataset("codecs");
    for codec in Codec::ALL {
        // warm cache: the ring serves the load-time warming reads
        let buffered = run_pagerank(&dir, codec, usize::MAX, false);
        let direct = run_pagerank(&dir, codec, usize::MAX, true);
        assert_eq!(buffered, direct, "codec {} warm-cache run diverged", codec.name());
    }
    // cache off: every iteration re-reads every shard through the ring
    let buffered = run_pagerank(&dir, Codec::None, 0, false);
    let direct = run_pagerank(&dir, Codec::None, 0, true);
    assert_eq!(buffered, direct, "cold-path run diverged");
    let _ = std::fs::remove_dir_all(dir.root.parent().unwrap());
}

#[test]
fn sssp_agrees_with_direct_io_and_either_fold() {
    let dir = build_dataset("sssp");
    let run = |direct_io: bool, simd: bool| {
        let engine = VswEngine::open(
            dir.clone(),
            EngineConfig {
                threads: 2,
                selective: false,
                cache_budget: 0,
                direct_io,
                simd,
                ..Default::default()
            },
        )
        .unwrap();
        let values = engine.run(&Sssp { source: 0 }).unwrap().values;
        values.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
    };
    let base = run(false, true);
    for (direct_io, simd) in [(true, true), (true, false), (false, false)] {
        assert_eq!(
            run(direct_io, simd),
            base,
            "sssp diverged at direct_io={direct_io} simd={simd}"
        );
    }
    let _ = std::fs::remove_dir_all(dir.root.parent().unwrap());
}
