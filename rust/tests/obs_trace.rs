//! Flight-recorder (GMTF) integration tests: install → record → finish →
//! read back.  The recorder is process-global, so every test here takes a
//! shared gate before touching it.
//!
//! The central property: for any sequence of records that fits the ring,
//! `read_records(path)` after `finish()` returns exactly the records that
//! were written, in order — and `trace-dump` renders one line per record.
//! A second test pins the ring bound: the on-disk log never exceeds twice
//! the configured cap, and the survivors are the newest records.

use graphmp::obs::{metrics, trace};
use graphmp::util::rng::SplitMix64;
use std::path::PathBuf;
use std::sync::Mutex;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gmp_trace_{tag}_{}.gmtf", std::process::id()))
}

/// A pseudo-random record of each kind, driven by the repo's own PRNG so
/// the "property test" is deterministic across runs.
fn synth_record(rng: &mut SplitMix64, i: u64) -> trace::TraceRecord {
    match rng.next_u64() % 3 {
        0 => trace::TraceRecord::Meta {
            app: format!("app-{}", rng.next_u64() % 7),
            epoch: rng.next_u64() % 100,
            sample: (rng.next_u64() % 32) as u32,
        },
        1 => trace::TraceRecord::Iter {
            epoch: rng.next_u64() % 100,
            iter: i,
            wall_ns: rng.next_u64() % (1 << 40),
            io_wait_ns: rng.next_u64() % (1 << 40),
            compute_ns: rng.next_u64() % (1 << 40),
            decode_ns: rng.next_u64() % (1 << 40),
            shards_processed: rng.next_u64() % 64,
            shards_skipped: rng.next_u64() % 64,
            active: rng.next_u64() % (1 << 30),
            read_bytes: rng.next_u64() % (1 << 44),
            cache_hits: rng.next_u64() % 1000,
            cache_misses: rng.next_u64() % 1000,
            window: rng.next_u64() % 16,
        },
        _ => trace::TraceRecord::Shard {
            iter: i,
            shard: rng.next_u64() % 256,
            acquire_ns: rng.next_u64() % (1 << 36),
            decode_ns: rng.next_u64() % (1 << 36),
            fold_ns: rng.next_u64() % (1 << 36),
        },
    }
}

#[test]
fn random_records_roundtrip_through_the_file() {
    let _g = gate();
    metrics::set_enabled(true);
    let path = tmp("roundtrip");
    trace::install(&path, 1024, 1).unwrap();
    assert!(trace::installed());

    let mut rng = SplitMix64::new(0xDECAF);
    let mut written = Vec::new();
    trace::record_run_start("pagerank", 7);
    written.push(trace::TraceRecord::Meta { app: "pagerank".into(), epoch: 7, sample: 1 });
    for i in 0..200 {
        let rec = synth_record(&mut rng, i);
        trace::record(rec.clone());
        written.push(rec);
    }
    let finished = trace::finish().expect("a recorder was installed");
    assert_eq!(finished, path);
    assert!(!trace::installed(), "finish must uninstall");

    let got = trace::read_records(&path).unwrap();
    assert_eq!(got, written, "decoded records must equal what was recorded, in order");

    // trace-dump's renderer: one line per record, kind-tagged
    let dump = trace::dump(&path).unwrap();
    let lines: Vec<&str> = dump.lines().collect();
    assert_eq!(lines.len(), written.len());
    for (line, rec) in lines.iter().zip(&written) {
        let prefix = match rec {
            trace::TraceRecord::Meta { .. } => "meta ",
            trace::TraceRecord::Iter { .. } => "iter ",
            trace::TraceRecord::Shard { .. } => "shard ",
        };
        assert!(line.starts_with(prefix), "{line:?} should start with {prefix:?}");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn ring_cap_bounds_the_file_and_keeps_the_newest() {
    let _g = gate();
    metrics::set_enabled(true);
    let path = tmp("ring");
    let cap = 8usize;
    trace::install(&path, cap, 0).unwrap();
    let total = 45u64;
    for i in 0..total {
        trace::record(trace::TraceRecord::Shard {
            iter: i,
            shard: i,
            acquire_ns: 1,
            decode_ns: 2,
            fold_ns: 3,
        });
    }
    trace::finish().unwrap();
    let got = trace::read_records(&path).unwrap();
    assert!(
        got.len() <= 2 * cap,
        "on-disk log must stay bounded at 2x the ring cap, got {} records",
        got.len()
    );
    // the tail of the log is the newest records, ending at total-1
    let last = got.last().unwrap();
    assert_eq!(
        *last,
        trace::TraceRecord::Shard {
            iter: total - 1,
            shard: total - 1,
            acquire_ns: 1,
            decode_ns: 2,
            fold_ns: 3
        }
    );
    let (records, dropped) = trace::totals();
    assert!(records >= total, "totals must count every record written");
    assert!(dropped > 0, "overflowing the ring must count drops");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn disabled_registry_silences_the_recorder() {
    let _g = gate();
    let path = tmp("silenced");
    trace::install(&path, 16, 1).unwrap();
    metrics::set_enabled(false);
    trace::record_run_start("pagerank", 1);
    trace::record(trace::TraceRecord::Shard {
        iter: 0,
        shard: 0,
        acquire_ns: 1,
        decode_ns: 1,
        fold_ns: 1,
    });
    assert!(!trace::shard_sampled(0), "GRAPHMP_OBS=0 must disable shard sampling too");
    metrics::set_enabled(true);
    trace::finish().unwrap();
    let got = trace::read_records(&path).unwrap();
    assert!(got.is_empty(), "disabled runs must leave only the header, got {got:?}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_files_fail_cleanly() {
    let _g = gate();
    let path = tmp("corrupt");
    std::fs::write(&path, b"NOPE....").unwrap();
    assert!(trace::read_records(&path).is_err(), "bad magic must be an error");
    // valid header, truncated record body
    let mut data = Vec::new();
    data.extend_from_slice(&trace::MAGIC);
    data.extend_from_slice(&trace::VERSION.to_le_bytes());
    data.push(2); // iter record kind, but no payload
    data.extend_from_slice(&[0u8; 4]);
    std::fs::write(&path, &data).unwrap();
    assert!(trace::read_records(&path).is_err(), "truncated records must be an error");
    let _ = std::fs::remove_file(&path);
}
