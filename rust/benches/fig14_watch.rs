//! Fig 14 (extension) — standing-query latency: `graphmp watch` advance
//! vs cold recompute over a live mutation stream.
//!
//! The driver registers standing queries (SSSP — monotone warm restart;
//! SpMV — single-pass Sum row maintenance), then streams delete-bearing
//! mutation batches.  After every ingest it measures the watch advance
//! (update-to-answer latency: re-derive the fixpoint and emit only the
//! changed `<vertex> <bits>` lines) against a full cold recompute of the
//! same epoch.  Two invariants fail the driver loudly:
//!
//! * every emission must equal the line diff of the two full dumps
//!   around it (the delta-only contract, deletes included);
//! * the summed watch-advance wall must beat the summed cold-recompute
//!   wall — otherwise the standing query is pointless.
//!
//! `--quick` (the CI bench-smoke mode): tiny dataset, small batches, and
//! a `fig_watch_latency` record appended to `$GRAPHMP_BENCH_JSON` if set.

use std::time::{Duration, Instant};

use graphmp::apps;
use graphmp::coordinator::benchjson::{self, BenchRecord};
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::report;
use graphmp::engine::standing;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::graph::mutation;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

/// Full bit-exact per-vertex rendering of a cold run (the dump file).
fn full_dump(engine: &VswEngine, app: &apps::AnyProgram) -> anyhow::Result<Vec<String>> {
    let r = engine.run_any(app)?;
    Ok((0..r.values.len()).map(|i| r.values.render_bits(i).expect("in range")).collect())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    let quick = args.has("quick");
    let dataset = if quick {
        Dataset::by_name("tiny")?
    } else {
        Dataset::by_name(
            &std::env::var("GRAPHMP_FIG14_DATASET").unwrap_or_else(|_| "twitter-s".into()),
        )?
    };
    let (rounds, batch_size) = if quick { (4usize, 500usize) } else { (8, 10_000) };
    println!(
        "Fig 14: standing-query advance vs cold recompute on {} ({rounds} x {batch_size} \
         mutations, deletes included)",
        dataset.name
    );

    // fresh mutable copy — the shared bench datasets must stay immutable
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("graphmp_fig14_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let mut edges = dataset.generate();
    let mut weights: Vec<f32> = Vec::new();
    let n = dataset.num_vertices();
    preprocess(dataset.name, &edges, n, &dir, &PreprocessConfig::default())?;

    let engine = VswEngine::open(dir.clone(), EngineConfig::default())?;
    let lanes = ["sssp", "spmv"];
    let mut dumps: Vec<Vec<String>> = Vec::new();
    for name in lanes {
        let app = apps::by_name(name)?;
        let out = standing::watch_advance(&dir, &engine, &app, None)?;
        assert!(out.registered, "{name}: first watch call must register");
        dumps.push(full_dump(&engine, &app)?);
    }

    let mut watch_wall = Duration::ZERO;
    let mut cold_wall = Duration::ZERO;
    let mut emitted = 0usize;
    let mut last_stats = graphmp::engine::RunStats::default();
    for r in 0..rounds {
        let batch =
            mutation::synth_batch(n, &edges, batch_size, 0.2, false, 0xF16_14 + r as u64);
        mutation::apply_batch(&mut edges, &mut weights, &batch)?;
        mutation::ingest(&dir, &batch, 0.01)?;
        engine.refresh_latest()?;

        for (i, name) in lanes.iter().enumerate() {
            let app = apps::by_name(name)?;
            let t0 = Instant::now();
            let out = standing::watch_advance(&dir, &engine, &app, None)?;
            watch_wall += t0.elapsed();
            let t1 = Instant::now();
            let new = full_dump(&engine, &app)?;
            cold_wall += t1.elapsed();
            let diff: Vec<String> = dumps[i]
                .iter()
                .zip(&new)
                .enumerate()
                .filter(|(_, (a, b))| a != b)
                .map(|(v, (_, b))| format!("{v} {b}"))
                .collect();
            assert_eq!(
                out.lines, diff,
                "{name}: round {r} emission diverged from the dump diff"
            );
            emitted += out.lines.len();
            dumps[i] = new;
            if *name == "sssp" {
                last_stats = out.stats;
            }
        }
    }

    assert!(
        watch_wall < cold_wall,
        "standing-query advance ({}) must beat cold recompute ({})",
        humansize::duration(watch_wall),
        humansize::duration(cold_wall)
    );

    let mut table = Table::new(
        &format!("Fig14 standing queries ({})", dataset.name),
        &["leg", "total", "detail"],
    );
    table.row(&[
        "watch".into(),
        humansize::duration(watch_wall),
        format!("{rounds} rounds x {} lanes, {emitted} changed lines emitted", lanes.len()),
    ]);
    table.row(&[
        "cold".into(),
        humansize::duration(cold_wall),
        format!("full recompute + dump per round ({:.2}x watch)", {
            cold_wall.as_secs_f64() / watch_wall.as_secs_f64().max(1e-9)
        }),
    ]);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    benchjson::record_if_requested(&BenchRecord::from_stats(
        "fig_watch_latency",
        watch_wall,
        &last_stats,
    ))?;
    let _ = std::fs::remove_dir_all(&dir.root);
    Ok(())
}
