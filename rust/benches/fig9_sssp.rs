//! Fig 9 — execution time of the five systems running **SSSP** (10
//! iterations, first includes loading) on the four datasets.
//!
//! Expected shape: selective scheduling lets both GraphMP variants and
//! GridGraph skip work (the paper observes GridGraph's third-iteration dip
//! on EU-2015); GraphChi is hit hardest because it re-reads + re-writes all
//! edge values regardless of frontier size.
//!
//! Beyond the paper's figure, this driver also runs the typed-lane apps of
//! the generalized `VertexProgram` API through the same five systems:
//! weighted SSSP (f32 over the per-edge weight lane), label propagation
//! (u64, Min) and MaxDeg (u32, Max) — the fig9-style registration the
//! conformance matrix verifies for correctness.

use graphmp::apps::{LabelProp, MaxDeg, Sssp, VertexProgram, WeightedSssp};
use graphmp::coordinator::experiment::{exec_time_typed, render_exec_figure};
use graphmp::coordinator::report;

fn main() -> anyhow::Result<()> {
    println!("Fig 9: SSSP execution time (10 iterations)");
    let sssp: &dyn VertexProgram = &Sssp { source: 0 };
    let rows = exec_time_typed(sssp, 10, false)?;
    let table = render_exec_figure("Fig9 SSSP exec time", &rows);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    println!("Fig 9b: weighted SSSP over the edge-weight lane");
    let wsssp: &dyn VertexProgram = &WeightedSssp { source: 0 };
    let rows = exec_time_typed(wsssp, 10, true)?;
    let table = render_exec_figure("Fig9b weighted-SSSP exec time", &rows);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    println!("Fig 9c: label propagation (u64 lane)");
    let lp: &dyn VertexProgram<u64> = &LabelProp;
    let rows = exec_time_typed(lp, 10, false)?;
    let table = render_exec_figure("Fig9c labelprop(u64) exec time", &rows);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    println!("Fig 9d: max reachable out-degree (u32 lane)");
    let md: &dyn VertexProgram<u32> = &MaxDeg;
    let rows = exec_time_typed(md, 10, false)?;
    let table = render_exec_figure("Fig9d maxdeg(u32) exec time", &rows);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
