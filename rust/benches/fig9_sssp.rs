//! Fig 9 — execution time of the five systems running **SSSP** (10
//! iterations, first includes loading) on the four datasets.
//!
//! Expected shape: selective scheduling lets both GraphMP variants and
//! GridGraph skip work (the paper observes GridGraph's third-iteration dip
//! on EU-2015); GraphChi is hit hardest because it re-reads + re-writes all
//! edge values regardless of frontier size.

use graphmp::apps::Sssp;
use graphmp::coordinator::experiment::{exec_time_figure, render_exec_figure};
use graphmp::coordinator::report;

fn main() -> anyhow::Result<()> {
    println!("Fig 9: SSSP execution time (10 iterations)");
    let rows = exec_time_figure(&Sssp { source: 0 }, 10)?;
    let table = render_exec_figure("Fig9 SSSP exec time", &rows);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
