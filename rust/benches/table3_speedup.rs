//! Table III — performance speedup ratios compared to GraphMP-C, for
//! PageRank / SSSP / WCC across the datasets (the condensed form of
//! Figs 8-10).
//!
//! Paper's headline cells: PageRank EU-2015 — GraphChi 12.5, X-Stream 54.5,
//! GridGraph 23.1, GraphMP-NC 7.4; SSSP EU-2015 — GraphChi 31.6; small
//! graphs (Twitter/UK-2007) — GraphMP-NC ≈ 1.0-1.2 because everything fits
//! cache either way.  Expected shape: same ordering, same ≈1.0 NC cells on
//! the small datasets, double-digit ratios for the streaming baselines.

use graphmp::apps::{self, VertexProgram};
use graphmp::coordinator::experiment::exec_time_figure;
use graphmp::coordinator::report;
use graphmp::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("GRAPHMP_TABLE3_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    println!("Table III: speedup ratios vs GraphMP-C ({iters} iters)");

    let mut table = Table::new(
        "TableIII speedups vs GraphMP-C",
        &["app", "dataset", "GraphChi", "X-Stream", "GridGraph", "GraphMP-NC"],
    );

    let apps_list: Vec<Box<dyn VertexProgram>> = vec![
        apps::by_name("pagerank")?.into_f32()?,
        apps::by_name("sssp")?.into_f32()?,
        apps::by_name("wcc")?.into_f32()?,
    ];
    for app in &apps_list {
        let rows = exec_time_figure(app.as_ref(), iters)?;
        let datasets: std::collections::BTreeSet<_> = rows.iter().map(|r| r.dataset).collect();
        for dataset in datasets {
            let get = |prefix: &str| -> f64 {
                rows.iter()
                    .find(|r| r.dataset == dataset && r.system.starts_with(prefix))
                    .map(|r| r.total.as_secs_f64())
                    .unwrap_or(0.0)
            };
            let base = get("GraphMP-C");
            table.row(&[
                app.name().into(),
                dataset.into(),
                report::ratio(base, get("psw")),
                report::ratio(base, get("esg")),
                report::ratio(base, get("dsw")),
                report::ratio(base, get("GraphMP-NC")),
            ]);
        }
    }
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
