//! Fig 11 — memory usage of the five systems running PageRank on each
//! dataset (GraphMP with and without the compressed cache).
//!
//! Paper numbers on EU-2015: GraphChi 10.65 GB, X-Stream 1.22 GB, GridGraph
//! 1.35 GB, GraphMP-NC 23.53 GB, GraphMP-C 91.37 GB (≈68 GB of compressed
//! cache holding all 91.8 B edges).  Expected shape: the streaming systems
//! tiny, GraphMP-NC = vertex-state-bound, GraphMP-C = cache-bound and the
//! largest — trading memory for the zero-disk-read steady state.

use graphmp::apps::PageRank;
use graphmp::baselines;
use graphmp::cache::Codec;
use graphmp::coordinator::datasets::paper_datasets;
use graphmp::coordinator::experiment::{bench_datasets, ensure_dataset, GraphMpVariant};
use graphmp::coordinator::report;
use graphmp::engine::VswEngine;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let _ = paper_datasets();
    println!("Fig 11: memory usage (PageRank)");
    let mut table = Table::new(
        "Fig11 memory usage, PageRank",
        &["dataset", "GraphChi", "X-Stream", "GridGraph", "GraphMP-NC", "GraphMP-C"],
    );
    for dataset in bench_datasets() {
        let dir = ensure_dataset(dataset)?;
        let edges = dataset.generate();
        let mut cells = vec![dataset.name.to_string()];
        for sys in ["psw", "esg", "dsw"] {
            let work = std::env::temp_dir().join(format!("graphmp_f11_{sys}_{}", dataset.name));
            let mut eng = baselines::by_name(sys, work)?;
            eng.prepare(&edges, dataset.num_vertices())?;
            cells.push(humansize::bytes(eng.memory_estimate()));
        }
        for variant in [GraphMpVariant::NoCache, GraphMpVariant::Cached(Codec::SnapLite)] {
            let engine = VswEngine::open(dir.clone(), variant.to_config(true, 2))?;
            let run = engine.run(&PageRank::default())?;
            cells.push(humansize::bytes(run.stats.memory_bytes));
        }
        table.row(&cells);
    }
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
