//! Fig 16 (extension) — observability overhead: the metrics registry plus
//! the flight recorder must cost **under 5%** of warm VSW wall time.
//!
//! The driver opens one engine, warms it, then interleaves measured runs
//! with the registry hot (and the GMTF recorder installed, sampling every
//! 16th shard — the production default) against runs with `set_enabled
//! (false)` (the `GRAPHMP_OBS=0` shape).  Minimum-of-N on both sides
//! squeezes out scheduler noise; the gate retries the measurement a
//! couple of times before failing, because a 5% bound on a fast warm run
//! is within CI jitter for a single sample.
//!
//! `--quick` (CI bench-smoke): tiny dataset, and a `fig_obs_overhead`
//! record appended to `$GRAPHMP_BENCH_JSON` if set.

use std::time::{Duration, Instant};

use graphmp::apps;
use graphmp::coordinator::benchjson::{self, BenchRecord};
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::report;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::obs::{metrics, trace};
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::DatasetDir;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

const MAX_OVERHEAD: f64 = 0.05;
const ATTEMPTS: usize = 3;

/// Min-of-N wall for one obs mode, interleaved by the caller.
fn min_wall(
    engine: &VswEngine,
    app: &apps::AnyProgram,
    runs: usize,
) -> anyhow::Result<(Duration, graphmp::engine::RunStats)> {
    let mut best = Duration::MAX;
    let mut stats = graphmp::engine::RunStats::default();
    for _ in 0..runs {
        let t0 = Instant::now();
        let r = engine.run_any(app)?;
        let wall = t0.elapsed();
        if wall < best {
            best = wall;
            stats = r.stats;
        }
    }
    Ok((best, stats))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    let quick = args.has("quick");
    let dataset = if quick {
        Dataset::by_name("tiny")?
    } else {
        Dataset::by_name(
            &std::env::var("GRAPHMP_FIG16_DATASET").unwrap_or_else(|_| "twitter-s".into()),
        )?
    };
    let runs = if quick { 7 } else { 5 };
    println!(
        "Fig 16: observability overhead on {} (min of {runs} warm runs, gate < {:.0}%)",
        dataset.name,
        MAX_OVERHEAD * 100.0
    );

    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("graphmp_fig16_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let edges = dataset.generate();
    preprocess(dataset.name, &edges, dataset.num_vertices(), &dir, &PreprocessConfig::default())?;
    let trace_path = dir.root.with_extension("gmtf");

    let engine = VswEngine::open(dir.clone(), EngineConfig::default())?;
    let app = apps::by_name("pagerank")?;
    // warm the cache and the allocator before anything is timed
    metrics::set_enabled(true);
    engine.run_any(&app)?;

    let mut on = Duration::MAX;
    let mut off = Duration::MAX;
    let mut on_stats = graphmp::engine::RunStats::default();
    let mut ratio = f64::INFINITY;
    for attempt in 1..=ATTEMPTS {
        // obs fully hot: registry + recorder at the production sample rate
        metrics::set_enabled(true);
        trace::install(&trace_path, trace::DEFAULT_CAP, trace::DEFAULT_SAMPLE)?;
        let (w_on, s_on) = min_wall(&engine, &app, runs)?;
        let _ = trace::finish();
        // the GRAPHMP_OBS=0 shape
        metrics::set_enabled(false);
        let (w_off, _) = min_wall(&engine, &app, runs)?;
        metrics::set_enabled(true);

        if w_on < on {
            on = w_on;
            on_stats = s_on;
        }
        off = off.min(w_off);
        ratio = on.as_secs_f64() / off.as_secs_f64().max(1e-9);
        println!(
            "  attempt {attempt}: obs-on {} vs obs-off {} ({:+.2}%)",
            humansize::duration(on),
            humansize::duration(off),
            (ratio - 1.0) * 100.0
        );
        if ratio < 1.0 + MAX_OVERHEAD {
            break;
        }
    }
    assert!(
        ratio < 1.0 + MAX_OVERHEAD,
        "observability overhead {:.2}% exceeds the {:.0}% gate (on {} vs off {})",
        (ratio - 1.0) * 100.0,
        MAX_OVERHEAD * 100.0,
        humansize::duration(on),
        humansize::duration(off),
    );

    let mut table = Table::new(
        &format!("Fig16 observability overhead ({})", dataset.name),
        &["leg", "wall", "detail"],
    );
    table.row(&[
        "obs on".into(),
        humansize::duration(on),
        format!("registry + GMTF recorder, shard sample 1/{}", trace::DEFAULT_SAMPLE),
    ]);
    table.row(&[
        "obs off".into(),
        humansize::duration(off),
        format!("GRAPHMP_OBS=0 shape; overhead {:+.2}%", (ratio - 1.0) * 100.0),
    ]);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    benchjson::record_if_requested(&BenchRecord::from_stats("fig_obs_overhead", on, &on_stats))?;
    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_dir_all(&dir.root);
    Ok(())
}
