//! Fig 15 (extension) — partitioned-execution speedup: the `partrun`
//! coordinator over N interval workers vs the same workload on N=1.
//!
//! Workers are in-process (`cluster::worker::spawn_local`: socketpair +
//! thread — the same protocol bytes as spawned `partworker` processes,
//! minus exec/connect noise), each pinned to a single compute thread, so
//! the measured speedup is purely the partition-level parallelism the
//! barrier protocol buys: N folds running concurrently between barriers,
//! with only changed values crossing them.
//!
//! Two invariants fail the driver loudly:
//!
//! * N=1 and N=4 values must be **byte-identical** to each other and to a
//!   plain single-process `run` (the bit-identity contract);
//! * N=4 must beat N=1 on wall clock — otherwise the partitioning is
//!   pointless.
//!
//! `--quick` (the CI bench-smoke mode): smaller graph, and a
//! `fig_part_speedup` record (the N=4 wall) appended to
//! `$GRAPHMP_BENCH_JSON` if set.

#[cfg(not(unix))]
fn main() {
    println!("Fig 15: skipped (partition workers ride Unix socketpairs)");
}

#[cfg(unix)]
fn main() -> anyhow::Result<()> {
    use std::time::{Duration, Instant};

    use graphmp::apps;
    use graphmp::cluster::{worker, Coordinator, PartitionManifest, StreamLink};
    use graphmp::coordinator::benchjson::{self, BenchRecord};
    use graphmp::coordinator::cli::Args;
    use graphmp::coordinator::report;
    use graphmp::engine::{EngineConfig, VswEngine};
    use graphmp::graph::generator;
    use graphmp::sharding::{preprocess, PreprocessConfig};
    use graphmp::storage::DatasetDir;
    use graphmp::util::bench::Table;
    use graphmp::util::humansize;

    /// One full partitioned pagerank run; returns (wall, stitched values).
    fn partitioned(
        dir: &DatasetDir,
        num_shards: usize,
        workers: usize,
        iters: usize,
    ) -> anyhow::Result<(Duration, Vec<String>)> {
        let manifest = PartitionManifest::balanced(num_shards, workers)?;
        let cfg = EngineConfig { max_iters: iters, threads: 1, ..Default::default() };
        let mut links = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..workers {
            let (stream, handle) = worker::spawn_local(dir.clone(), cfg.clone(), None)?;
            links.push(StreamLink::new(stream));
            handles.push(handle);
        }
        let mut coord = Coordinator::new(manifest, links)?;
        let t0 = Instant::now();
        let summary = coord.run("pagerank", iters, true)?;
        let wall = t0.elapsed();
        drop(coord);
        for h in handles {
            h.join().expect("worker thread panicked")?;
        }
        Ok((wall, summary.values))
    }

    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    let quick = args.has("quick");
    let (scale, num_edges, iters) =
        if quick { (14u32, 600_000u64, 10usize) } else { (16, 4_000_000, 10) };
    let n = 1usize << scale;
    println!(
        "Fig 15: partitioned pagerank speedup, rmat scale {scale} (|V|={} |E|={}) x {iters} iters",
        humansize::count(n as u64),
        humansize::count(num_edges),
    );

    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("graphmp_fig15_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let edges = generator::rmat(scale, num_edges, generator::RmatParams::default(), 15);
    // shard fine enough that 4 workers each own a real run of shards
    let cfg = PreprocessConfig {
        max_edges_per_shard: (edges.len() / 16).max(4096),
        bloom_fpr: 0.01,
    };
    preprocess("fig15", &edges, n, &dir, &cfg)?;
    let engine = VswEngine::open(
        dir.clone(),
        EngineConfig { max_iters: iters, threads: 1, ..Default::default() },
    )?;
    let p = engine.property().num_shards();
    anyhow::ensure!(p >= 4, "fig15 graph must span at least 4 shards, got {p}");

    // the single-process truth (and the RunStats the record rides on)
    let reference = engine.run_any(&apps::by_name("pagerank")?)?;
    let want: Vec<String> =
        (0..reference.values.len()).map(|v| reference.values.render_bits(v).unwrap()).collect();

    // best-of-2 per worker count to damp scheduler noise
    let mut walls = Vec::new();
    for workers in [1usize, 4] {
        let mut best = Duration::MAX;
        for _ in 0..2 {
            let (wall, values) = partitioned(&dir, p, workers, iters)?;
            assert_eq!(
                values, want,
                "N={workers} partitioned values diverged from the single-process run"
            );
            best = best.min(wall);
        }
        walls.push((workers, best));
    }
    let (n1, n4) = (walls[0].1, walls[1].1);
    let speedup = n1.as_secs_f64() / n4.as_secs_f64().max(1e-9);

    let mut table =
        Table::new("Fig15 partitioned speedup (pagerank)", &["workers", "wall", "speedup"]);
    table.row(&["1".into(), humansize::duration(n1), "1.00x".into()]);
    table.row(&["4".into(), humansize::duration(n4), format!("{speedup:.2}x")]);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    assert!(
        n4 < n1,
        "N=4 ({}) must beat N=1 ({}) — partitioning bought nothing",
        humansize::duration(n4),
        humansize::duration(n1),
    );

    benchjson::record_if_requested(&BenchRecord::from_stats(
        "fig_part_speedup",
        n4,
        &reference.stats,
    ))?;
    let _ = std::fs::remove_dir_all(&dir.root);
    Ok(())
}
