//! Micro-benchmarks of the hot paths the perf pass (EXPERIMENTS.md §Perf)
//! optimizes: native shard update, Bloom probe, codec throughput, thread
//! pool dispatch, shard (de)serialization, and the PJRT kernel call.

use std::sync::Arc;

use graphmp::apps::{PageRank, ProgramContext};
use graphmp::bloom::BloomFilter;
use graphmp::cache::Codec;
use graphmp::engine::Backend;
use graphmp::graph::csr::Csr;
use graphmp::graph::generator;
use graphmp::runtime::ShardRuntime;
use graphmp::storage::shardfile;
use graphmp::util::bench::{black_box, Bench, Table};
use graphmp::util::humansize;
use graphmp::util::rng::Xoshiro256;
use graphmp::util::threadpool::ThreadPool;

fn main() -> anyhow::Result<()> {
    let bench = Bench::default();
    let mut table = Table::new("micro hot paths", &["path", "median", "throughput", "cv%"]);

    // a realistic power-law shard: 2048-vertex interval, ~16K edges
    let edges: Vec<(u32, u32)> = generator::rmat(14, 120_000, generator::RmatParams::default(), 3)
        .into_iter()
        .filter(|&(_, d)| d < 2048)
        .take(16_384)
        .collect();
    let csr = Csr::from_edges(0, 2048, &edges);
    let n_edges = csr.num_edges() as u64;
    let num_v = 1 << 14;
    let mut rng = Xoshiro256::seed_from_u64(1);
    let src: Vec<f32> = (0..num_v).map(|_| rng.next_f32()).collect();
    let out_deg: Vec<u32> = (0..num_v).map(|_| 1 + rng.gen_range(40) as u32).collect();
    let ctx = ProgramContext { num_vertices: num_v as u64 };
    let app = PageRank::default();

    // --- native shard update (the engine's inner loop) ---------------------
    let stats = bench.run(|| {
        let out = Backend::Native.process_shard(&app, &csr, &src, &out_deg, &ctx).unwrap();
        black_box(out);
    });
    table.row(&[
        "native shard update".into(),
        humansize::duration(stats.median()),
        format!("{}/s", humansize::count((n_edges as f64 / stats.median().as_secs_f64()) as u64)),
        format!("{:.1}", stats.cv_percent()),
    ]);

    // --- bloom probe --------------------------------------------------------
    let mut bloom = BloomFilter::with_capacity(n_edges as usize, 0.01);
    for &(s, _) in &edges {
        bloom.insert(s as u64);
    }
    let keys: Vec<u64> = (0..10_000u64).map(|k| k * 7919).collect();
    let stats = bench.run(|| {
        let mut hits = 0u32;
        for &k in &keys {
            hits += bloom.contains(k) as u32;
        }
        black_box(hits);
    });
    table.row(&[
        "bloom probe ×10k".into(),
        humansize::duration(stats.median()),
        format!("{}/s", humansize::count((10_000.0 / stats.median().as_secs_f64()) as u64)),
        format!("{:.1}", stats.cv_percent()),
    ]);

    // --- codecs --------------------------------------------------------------
    let payload = shardfile::to_bytes(&csr);
    for codec in Codec::ALL {
        let compressed = codec.compress(&payload)?;
        let stats = bench.run(|| {
            let shard = codec.decompress_shard(black_box(&compressed)).unwrap();
            black_box(shard.num_edges());
        });
        table.row(&[
            format!("decompress {}", codec.name()),
            humansize::duration(stats.median()),
            format!(
                "{}/s",
                humansize::bytes((payload.len() as f64 / stats.median().as_secs_f64()) as u64)
            ),
            format!("{:.1}", stats.cv_percent()),
        ]);
    }

    // --- thread pool dispatch -------------------------------------------------
    let pool = ThreadPool::new(4);
    let stats = bench.run(|| {
        pool.parallel_for(64, |i| {
            black_box(i);
        });
    });
    table.row(&[
        "pool dispatch (64 items)".into(),
        humansize::duration(stats.median()),
        format!("{}/s", humansize::count((64.0 / stats.median().as_secs_f64()) as u64)),
        format!("{:.1}", stats.cv_percent()),
    ]);

    // --- shard serialization ----------------------------------------------------
    let stats = bench.run(|| {
        let bytes = shardfile::to_bytes(black_box(&csr));
        black_box(shardfile::from_bytes(&bytes).unwrap());
    });
    table.row(&[
        "shard ser+de".into(),
        humansize::duration(stats.median()),
        format!(
            "{}/s",
            humansize::bytes((payload.len() as f64 / stats.median().as_secs_f64()) as u64)
        ),
        format!("{:.1}", stats.cv_percent()),
    ]);

    // --- PJRT kernel invocation (if artifacts exist) -----------------------------
    let adir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if adir.join("manifest.json").exists() {
        let rt = Arc::new(ShardRuntime::load(&adir)?);
        let contrib: Vec<f32> = csr.col.iter().map(|&u| src[u as usize]).collect();
        let mut dst_local = Vec::with_capacity(csr.num_edges());
        for (i, (_, row)) in csr.iter_rows().enumerate() {
            dst_local.extend(std::iter::repeat_n(i as u32, row.len()));
        }
        let quick = Bench::quick();
        let stats = quick.run(|| {
            let out = rt.pr_shard(&contrib, &dst_local, 1e-3, 2048).unwrap();
            black_box(out);
        });
        table.row(&[
            "PJRT pr_shard call".into(),
            humansize::duration(stats.median()),
            format!(
                "{}/s",
                humansize::count((n_edges as f64 / stats.median().as_secs_f64()) as u64)
            ),
            format!("{:.1}", stats.cv_percent()),
        ]);
    }

    table.print();
    graphmp::coordinator::report::append_markdown(
        &graphmp::coordinator::report::results_path(),
        &table,
    )?;
    Ok(())
}
