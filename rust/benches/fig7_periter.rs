//! Fig 7 — per-iteration execution time + activation ratio: GraphMP vs
//! GraphMat (in-memory) on Twitter, PageRank / SSSP / WCC, loading time
//! excluded.
//!
//! Paper numbers (processing only): PR 28 s (GraphMat) vs 22 s (GraphMP);
//! SSSP 1.3 s vs 9.9 s; WCC 1.5 s vs 2.1 s — i.e. GraphMP wins PR, the
//! in-memory engine wins the frontier apps.  Expected shape: same ordering.
//! The adaptive column is the governor ablation: same app, same dataset,
//! window and shard order chosen by the per-iteration feedback loop
//! (results bit-identical, time and io-wait may differ).
//!
//! `--quick` (the CI bench-smoke mode): tiny dataset, short PageRank
//! horizon, and machine-readable records appended to
//! `$GRAPHMP_BENCH_JSON` if set — the headline `fig7_periter` run plus the
//! compressed-domain ablation pair (`fig7_gather_stream` /
//! `fig7_gather_decode`: same app, same compressed cache, hits streamed
//! into the gather vs decoded to a CSR per hit).  The `decode` column is
//! the `decode_ns` split: time spent turning cached bytes into walkable
//! form, as opposed to gathering over them.

use std::time::{Duration, Instant};

use graphmp::apps::{self, VertexProgram};
use graphmp::baselines::{InMemEngine, OocEngine};
use graphmp::cache::Codec;
use graphmp::coordinator::benchjson::{self, BenchRecord};
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::experiment::{
    ensure_dataset, run_graphmp, run_graphmp_adaptive, run_graphmp_cfg, GraphMpVariant,
};
use graphmp::coordinator::report;
use graphmp::engine::RunStats;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let t_bench = Instant::now();
    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    let quick = args.has("quick");
    let dataset = Dataset::by_name(if quick { "tiny" } else { "twitter-s" })?;
    println!("Fig 7: per-iteration, GraphMP vs GraphMat on {}", dataset.name);
    let dir = ensure_dataset(dataset)?;
    let edges = dataset.generate();

    let pr_iters = if quick { 5 } else { 10 };
    let apps_list: Vec<(Box<dyn VertexProgram>, usize)> = vec![
        (apps::by_name("pagerank")?.into_f32()?, pr_iters),
        (apps::by_name("sssp")?.into_f32()?, 0),
        (apps::by_name("wcc")?.into_f32()?, 0),
    ];
    let mut table = Table::new(
        &format!("Fig7 processing time (loading excluded), {}", dataset.name),
        &[
            "app",
            "GraphMP (fixed)",
            "GraphMP (adaptive)",
            "window",
            "io wait (a)",
            "compute (a)",
            "decode (a)",
            "GraphMat",
            "GraphMP iters",
            "GraphMat iters",
        ],
    );
    let mut gate_stats: Option<RunStats> = None;

    for (app, iters) in &apps_list {
        let variant = GraphMpVariant::Cached(Codec::SnapLite);
        let (g, _) = run_graphmp(&dir, variant, true, app.as_ref(), *iters)?;
        let (ga, _) = run_graphmp_adaptive(&dir, variant, true, app.as_ref(), *iters)?;
        if gate_stats.is_none() {
            gate_stats = Some(ga.stats.clone());
        }
        let mut inmem = InMemEngine::new();
        inmem.prepare(&edges, dataset.num_vertices())?;
        let m = inmem.run(app.as_ref(), if *iters == 0 { 10_000 } else { *iters })?;
        table.row(&[
            app.name().into(),
            humansize::duration(g.stats.total_wall),
            humansize::duration(ga.stats.total_wall),
            format!("2→{}", ga.stats.final_prefetch_depth()),
            // acquisition vs kernel time: with the prefetch pipeline the io
            // wait column is only the *unhidden* part of shard loading
            humansize::duration(ga.stats.total_io_wait()),
            humansize::duration(ga.stats.total_compute()),
            // decode_ns: byte→walkable work (runs on the I/O pool, so it
            // is hidden behind compute, not a subset of either column)
            humansize::duration(Duration::from_nanos(ga.stats.total_decode_ns())),
            humansize::duration(m.total_wall),
            g.stats.num_iters().to_string(),
            m.iter_walls.len().to_string(),
        ]);
        // activation curve (Fig 7 left column)
        print!("  {} activation ratio:", app.name());
        for &s in [0usize, 1, 2, 4, 8].iter().filter(|&&s| s < g.stats.iters.len()) {
            print!(" i{s}={:.4}", g.stats.iters[s].active_ratio);
        }
        println!();
    }
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    if let Some(stats) = &gate_stats {
        benchjson::record_if_requested(&BenchRecord::from_stats(
            "fig7_periter",
            t_bench.elapsed(),
            stats,
        ))?;
    }

    // ---- compressed-domain ablation: the same PageRank workload over the
    // same compressed (snaplite) cache, with hits streamed into the gather
    // fold (the default) vs decoded to a fresh CSR per hit (the pre-
    // streaming behavior).  Both rows land in $GRAPHMP_BENCH_JSON so the
    // bench-smoke gate tracks the pair PR over PR.
    let pr = apps::by_name("pagerank")?.into_f32()?;
    let mut ablation = Table::new(
        &format!("Fig7 ablation: compressed-domain gather vs decode, {}", dataset.name),
        &["path", "total", "io wait", "compute", "decode", "hit ratio"],
    );
    for (label, stream) in [("stream (default)", true), ("decode per hit", false)] {
        let t0 = Instant::now();
        let mut cfg = GraphMpVariant::Cached(Codec::SnapLite).to_config(true, pr_iters);
        cfg.stream_gather = stream;
        let (run, _load) = run_graphmp_cfg(&dir, cfg, pr.as_ref())?;
        ablation.row(&[
            label.into(),
            humansize::duration(run.stats.total_wall),
            humansize::duration(run.stats.total_io_wait()),
            humansize::duration(run.stats.total_compute()),
            humansize::duration(Duration::from_nanos(run.stats.total_decode_ns())),
            format!("{:.1}%", run.stats.cache_hit_ratio() * 100.0),
        ]);
        benchjson::record_if_requested(&BenchRecord::from_stats(
            if stream { "fig7_gather_stream" } else { "fig7_gather_decode" },
            t0.elapsed(),
            &run.stats,
        ))?;
    }
    ablation.print();
    report::append_markdown(&report::results_path(), &ablation)?;
    Ok(())
}
