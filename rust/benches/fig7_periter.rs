//! Fig 7 — per-iteration execution time + activation ratio: GraphMP vs
//! GraphMat (in-memory) on Twitter, PageRank / SSSP / WCC, loading time
//! excluded.
//!
//! Paper numbers (processing only): PR 28 s (GraphMat) vs 22 s (GraphMP);
//! SSSP 1.3 s vs 9.9 s; WCC 1.5 s vs 2.1 s — i.e. GraphMP wins PR, the
//! in-memory engine wins the frontier apps.  Expected shape: same ordering.

use graphmp::apps::{self, VertexProgram};
use graphmp::baselines::{InMemEngine, OocEngine};
use graphmp::cache::Codec;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::experiment::{ensure_dataset, run_graphmp, GraphMpVariant};
use graphmp::coordinator::report;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let dataset = Dataset::by_name("twitter-s")?;
    println!("Fig 7: per-iteration, GraphMP vs GraphMat on {}", dataset.name);
    let dir = ensure_dataset(dataset)?;
    let edges = dataset.generate();

    let apps_list: Vec<(Box<dyn VertexProgram>, usize)> = vec![
        (apps::by_name("pagerank")?, 10),
        (apps::by_name("sssp")?, 0),
        (apps::by_name("wcc")?, 0),
    ];
    let mut table = Table::new(
        "Fig7 processing time (loading excluded), twitter-s",
        &["app", "GraphMP", "io wait", "compute", "GraphMat", "GraphMP iters", "GraphMat iters"],
    );

    for (app, iters) in &apps_list {
        let (g, _) = run_graphmp(
            &dir,
            GraphMpVariant::Cached(Codec::SnapLite),
            true,
            app.as_ref(),
            *iters,
        )?;
        let mut inmem = InMemEngine::new();
        inmem.prepare(&edges, dataset.num_vertices())?;
        let m = inmem.run(app.as_ref(), if *iters == 0 { 10_000 } else { *iters })?;
        table.row(&[
            app.name().into(),
            humansize::duration(g.stats.total_wall),
            // acquisition vs kernel time: with the prefetch pipeline the io
            // wait column is only the *unhidden* part of shard loading
            humansize::duration(g.stats.total_io_wait()),
            humansize::duration(g.stats.total_compute()),
            humansize::duration(m.total_wall),
            g.stats.num_iters().to_string(),
            m.iter_walls.len().to_string(),
        ]);
        // activation curve (Fig 7 left column)
        print!("  {} activation ratio:", app.name());
        for &s in [0usize, 1, 2, 4, 8].iter().filter(|&&s| s < g.stats.iters.len()) {
            print!(" i{s}={:.4}", g.stats.iters[s].active_ratio);
        }
        println!();
    }
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
