//! Fig 6 — data loading: GraphMP vs GraphMat (in-memory baseline) on the
//! Twitter stand-in, PageRank.
//!
//! Paper numbers: GraphMat needs 122 GB and 390 s to load Twitter before it
//! can run anything; GraphMP needs 7.3 GB and 30 s (constructing Bloom
//! filters and pre-warming the compressed cache).  Expected shape here: the
//! in-memory engine's load memory is a large multiple of GraphMP's working
//! set, and load time is higher, while its per-iteration time is lower.
//!
//! Three GraphMP rows form the I/O-pipeline ablation: synchronous loads,
//! the fixed 2-deep prefetch window, and the adaptive governor (window
//! sized per iteration from the io-wait feedback, shards issued
//! hottest-first).
//!
//! `--quick` (the CI bench-smoke mode): tiny dataset and a machine-readable
//! record appended to `$GRAPHMP_BENCH_JSON` if set.

use std::time::Instant;

use graphmp::apps::PageRank;
use graphmp::baselines::{InMemEngine, OocEngine};
use graphmp::cache::Codec;
use graphmp::coordinator::benchjson::{self, BenchRecord};
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::experiment::ensure_dataset;
use graphmp::coordinator::report;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let t_bench = Instant::now();
    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    let quick = args.has("quick");
    let dataset = Dataset::by_name(if quick { "tiny" } else { "twitter-s" })?;
    println!("Fig 6: loading cost on {} (PageRank)", dataset.name);
    let dir = ensure_dataset(dataset)?;
    let edges = dataset.generate();
    graphmp::storage::io::set_throttle(
        graphmp::coordinator::experiment::figure_throttle_mbps() << 20,
    );

    let mut table = Table::new(
        &format!("Fig6 loading: GraphMP vs GraphMat ({})", dataset.name),
        &[
            "system",
            "window",
            "load time",
            "memory",
            "10-iter run",
            "io wait",
            "compute",
            "load+run",
        ],
    );
    let mut gate_stats = None;

    // GraphMP-C: open() performs the loading phase (bloom + cache warm,
    // with the shard read-ahead overlapping disk and compression); all
    // three prefetch settings run so the io_wait column shows the overlap
    // the pipelined engine buys and what the governor does on top
    for (label, depth, adaptive) in [
        ("GraphMP-C (sync io)", 0usize, false),
        ("GraphMP-C (pipelined)", 2, false),
        ("GraphMP-C (adaptive)", 2, true),
    ] {
        let engine = VswEngine::open(
            dir.clone(),
            EngineConfig {
                max_iters: 10,
                cache_codec: Codec::SnapLite,
                prefetch_depth: depth,
                adaptive,
                ..Default::default()
            },
        )?;
        let load = engine.load_wall;
        let run = engine.run(&PageRank::default())?;
        let window = if adaptive {
            format!("{}→{}", depth, run.stats.final_prefetch_depth())
        } else {
            depth.to_string()
        };
        table.row(&[
            label.into(),
            window,
            humansize::duration(load),
            humansize::bytes(run.stats.memory_bytes),
            humansize::duration(run.stats.total_wall),
            humansize::duration(run.stats.total_io_wait()),
            humansize::duration(run.stats.total_compute()),
            humansize::duration(load + run.stats.total_wall),
        ]);
        if adaptive {
            gate_stats = Some(run.stats.clone());
        }
    }

    // GraphMat stand-in: its load phase parses the text edge list (the
    // paper's CSV ingestion) — materialize the file untimed, then time the
    // read+parse+build like the paper times GraphMat's loading
    let csv = std::env::temp_dir().join(format!("graphmp_fig6_{}.txt", dataset.name));
    if !csv.exists() {
        graphmp::storage::io::set_throttle(0);
        graphmp::graph::edgelist::write_text(&csv, &edges)?;
        graphmp::storage::io::set_throttle(
            graphmp::coordinator::experiment::figure_throttle_mbps() << 20,
        );
    }
    let mut inmem = InMemEngine::new();
    let t0 = Instant::now();
    inmem.prepare_from_text(&csv, dataset.num_vertices())?;
    let load = t0.elapsed();
    let run = inmem.run(&PageRank::default(), 10)?;
    table.row(&[
        "GraphMat (inmem)".into(),
        "-".into(),
        humansize::duration(load),
        humansize::bytes(run.memory_bytes),
        humansize::duration(run.total_wall),
        "-".into(), // fully in-memory: no per-iteration shard acquisition
        humansize::duration(run.total_wall),
        humansize::duration(load + run.total_wall),
    ]);

    graphmp::storage::io::set_throttle(0);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    if let Some(stats) = &gate_stats {
        benchjson::record_if_requested(&BenchRecord::from_stats(
            "fig6_loading",
            t_bench.elapsed(),
            stats,
        ))?;
    }
    Ok(())
}
