//! Fig 8 — execution time of GraphChi, X-Stream, GridGraph, GraphMP-NC and
//! GraphMP-C running **PageRank** (10 iterations, first includes loading)
//! on the four datasets.
//!
//! Expected shape (paper Table III column "PageRank"): GraphMP-C fastest;
//! on cache-resident graphs GraphMP-NC ≈ GraphMP-C (ratios 1.0-1.1); the
//! baselines one to two orders slower, X-Stream slowest on big graphs.
//! Set GRAPHMP_BENCH_FULL=1 for all four datasets.

use graphmp::apps::PageRank;
use graphmp::coordinator::experiment::{exec_time_figure, render_exec_figure};
use graphmp::coordinator::report;

fn main() -> anyhow::Result<()> {
    println!("Fig 8: PageRank execution time (10 iterations)");
    let rows = exec_time_figure(&PageRank::default(), 10)?;
    let table = render_exec_figure("Fig8 PageRank exec time", &rows);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
