//! Fig 13 (repro extension) — saturating the cold path.
//!
//! Two ablations the paper's Fig 5/7 framing implies but never isolates:
//!
//! * **Cold shard reads**: with the cache disabled every iteration
//!   re-reads every shard, so the gather is bounded by how fast bytes
//!   leave the device.  Buffered `pread` vs the `O_DIRECT` submission
//!   ring (`--direct-io`), reported as effective read GB/s — the ring's
//!   queue depth follows the governor's window.
//! * **SIMD gather folds**: warm mode-1 cache (no I/O after warming), the
//!   vectorized run kernels vs the scalar fold on the same rows.  Results
//!   are bit-identical by construction; only the fold time may move.
//!
//! `--quick` (CI bench-smoke): tiny dataset, short horizon, and two
//! records appended to `$GRAPHMP_BENCH_JSON` — `fig_cold_gbps` (the
//! direct-io cold run) and `fig_simd_fold` (the simd-on warm run) — so
//! bench-compare gates both paths PR over PR.

use std::time::Instant;

use graphmp::apps;
use graphmp::cache::Codec;
use graphmp::coordinator::benchjson::{self, BenchRecord};
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::experiment::{ensure_dataset, run_graphmp_cfg, GraphMpVariant};
use graphmp::coordinator::report;
use graphmp::engine::{simd, VswEngine};
use graphmp::storage::io;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    let quick = args.has("quick");
    let dataset = Dataset::by_name(if quick { "tiny" } else { "twitter-s" })?;
    println!("Fig 13: cold-path direct I/O + SIMD gather folds on {}", dataset.name);
    let dir = ensure_dataset(dataset)?;
    let pr = apps::by_name("pagerank")?.into_f32()?;
    let iters = if quick { 5 } else { 10 };

    // ---- cold path: cache off, every iteration re-reads from disk -------
    let mut cold = Table::new(
        &format!("Fig13 cold shard reads (cache off), {}", dataset.name),
        &["path", "total", "read GB/s", "io wait", "ring (direct/fallback)"],
    );
    for (label, direct) in [("buffered pread", false), ("direct-io ring", true)] {
        let mut cfg = GraphMpVariant::NoCache.to_config(false, iters);
        cfg.direct_io = direct;
        let before = io::snapshot();
        let t0 = Instant::now();
        let engine = VswEngine::open(dir.clone(), cfg)?;
        let result = engine.run(pr.as_ref())?;
        let wall = t0.elapsed();
        let read = io::snapshot().since(&before).bytes_read;
        let gbps = read as f64 / 1e9 / result.stats.total_wall.as_secs_f64().max(1e-9);
        let ring = match engine.direct_reader() {
            Some(r) => {
                let (d, f) = r.counts();
                format!("{d}/{f}")
            }
            None => "—".into(),
        };
        cold.row(&[
            label.into(),
            humansize::duration(result.stats.total_wall),
            format!("{gbps:.2}"),
            humansize::duration(result.stats.total_io_wait()),
            ring,
        ]);
        if direct {
            benchjson::record_if_requested(&BenchRecord::from_stats(
                "fig_cold_gbps",
                wall,
                &result.stats,
            ))?;
        }
    }
    cold.print();
    report::append_markdown(&report::results_path(), &cold)?;

    // ---- SIMD fold: warm mode-1 cache, zero steady-state I/O ------------
    let mut fold = Table::new(
        &format!("Fig13 gather fold, warm cache, {} (cpu: {})", dataset.name, simd::level()),
        &["fold", "total", "compute", "hit ratio"],
    );
    for (label, on) in [("simd", true), ("scalar", false)] {
        let mut cfg = GraphMpVariant::Cached(Codec::None).to_config(false, iters);
        cfg.simd = on;
        let t0 = Instant::now();
        let (run, _load) = run_graphmp_cfg(&dir, cfg, pr.as_ref())?;
        let wall = t0.elapsed();
        fold.row(&[
            label.into(),
            humansize::duration(run.stats.total_wall),
            humansize::duration(run.stats.total_compute()),
            format!("{:.1}%", run.stats.cache_hit_ratio() * 100.0),
        ]);
        if on {
            benchjson::record_if_requested(&BenchRecord::from_stats(
                "fig_simd_fold",
                wall,
                &run.stats,
            ))?;
        }
    }
    fold.print();
    report::append_markdown(&report::results_path(), &fold)?;
    Ok(())
}
