//! Fig 10 — execution time of the five systems running **WCC** (10
//! iterations, first includes loading) on the four datasets.
//!
//! Expected shape: like Fig 8 with a stronger GraphMP-NC showing (WCC's
//! min-label propagation converges region by region, so selective
//! scheduling recovers part of the cache's advantage).

use graphmp::apps::Wcc;
use graphmp::coordinator::experiment::{exec_time_figure, render_exec_figure};
use graphmp::coordinator::report;

fn main() -> anyhow::Result<()> {
    println!("Fig 10: WCC execution time (10 iterations)");
    let rows = exec_time_figure(&Wcc, 10)?;
    let table = render_exec_figure("Fig10 WCC exec time", &rows);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
