//! Ablation — the selective-scheduling threshold (§II-D.1): the paper fixes
//! it at 0.001 and notes "users can choose a better value for specific
//! applications".  Sweep it for SSSP and WCC and report total time, shard
//! skips and Bloom-probe overhead.
//!
//! Expected shape: 0 (never selective) pays full processing; too-high
//! thresholds waste time probing filters while nearly every shard is still
//! active; the sweet spot sits where the frontier is genuinely sparse —
//! for SSSP that is most of the run, so higher thresholds keep winning.

use graphmp::apps::{self, VertexProgram};
use graphmp::cache::Codec;
use graphmp::coordinator::experiment::{ablation_dataset, ensure_dataset};
use graphmp::coordinator::report;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let dataset = ablation_dataset();
    println!("Ablation: selective-scheduling threshold on {}", dataset.name);
    let dir = ensure_dataset(dataset)?;

    let apps_list: Vec<Box<dyn VertexProgram>> =
        vec![apps::by_name("sssp")?.into_f32()?, apps::by_name("wcc")?.into_f32()?];
    let thresholds = [0.0, 0.0001, 0.001, 0.01, 0.1, 1.0];

    let mut table = Table::new(
        &format!("bloom threshold sweep on {}", dataset.name),
        &["app", "threshold", "iters", "total", "shards skipped", "shards processed"],
    );
    for app in &apps_list {
        for &thr in &thresholds {
            let engine = VswEngine::open(
                dir.clone(),
                EngineConfig {
                    selective: thr > 0.0,
                    selective_threshold: thr,
                    cache_codec: Codec::SnapLite,
                    ..Default::default()
                },
            )?;
            let run = engine.run(app.as_ref())?;
            let skipped: usize = run.stats.iters.iter().map(|i| i.shards_skipped).sum();
            let processed: usize = run.stats.iters.iter().map(|i| i.shards_processed).sum();
            table.row(&[
                app.name().into(),
                if thr == 0.0 { "off".into() } else { format!("{thr}") },
                run.stats.num_iters().to_string(),
                humansize::duration(run.stats.total_wall),
                skipped.to_string(),
                processed.to_string(),
            ]);
        }
    }
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
