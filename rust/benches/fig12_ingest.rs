//! Fig 12 (extension) — dynamic-graph update throughput and incremental
//! recomputation.
//!
//! The paper's pipeline is strictly static (preprocess once, read
//! forever); this driver measures the delta-shard subsystem that lifts
//! that restriction: (1) `ingest` throughput — mutations/second absorbed
//! into per-interval delta shards with per-epoch Bloom rebuilds, (2)
//! incremental restart — SSSP re-converging from the previous epoch's
//! fixpoint seeded with the inserted edges' sources, vs a cold start on
//! the mutated graph, and (3) compaction — merged shard rewrite time.
//! Warm and cold must agree exactly, and post-compaction results must be
//! bit-identical; the driver fails loudly otherwise.
//!
//! `--quick` (the CI bench-smoke mode): tiny dataset, small batches, and
//! `fig_ingest_*` records appended to `$GRAPHMP_BENCH_JSON` if set.

use std::time::Instant;

use graphmp::apps::Sssp;
use graphmp::coordinator::benchjson::{self, BenchRecord};
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::report;
use graphmp::engine::{EngineConfig, RunStats, VswEngine, WarmStart};
use graphmp::graph::mutation;
use graphmp::runtime::EpochManifest;
use graphmp::sharding::{preprocess, PreprocessConfig};
use graphmp::storage::property::Property;
use graphmp::storage::DatasetDir;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    let quick = args.has("quick");
    let dataset = if quick {
        Dataset::by_name("tiny")?
    } else {
        Dataset::by_name(
            &std::env::var("GRAPHMP_FIG12_DATASET").unwrap_or_else(|_| "twitter-s".into()),
        )?
    };
    let (rounds, batch_size) = if quick { (4usize, 1_000usize) } else { (8, 20_000) };
    println!(
        "Fig 12: delta-shard ingest + incremental recomputation on {} ({rounds} x {batch_size} \
         mutations)",
        dataset.name
    );

    // fresh mutable copy — the shared bench datasets must stay immutable
    let dir = DatasetDir::new(
        std::env::temp_dir().join(format!("graphmp_fig12_{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&dir.root);
    let edges = dataset.generate();
    preprocess(dataset.name, &edges, dataset.num_vertices(), &dir, &PreprocessConfig::default())?;

    // cold fixpoint at the base epoch (the warm start's input)
    let app = Sssp { source: 0 };
    let engine = VswEngine::open(dir.clone(), EngineConfig::default())?;
    let base = engine.run(&app)?;
    drop(engine);

    // 1) update throughput: R insert-only batches (insert-only keeps the
    // incremental leg eligible; deletes are exercised by the test suite)
    let t_apply = Instant::now();
    let mut applied = 0u64;
    for r in 0..rounds {
        let batch = mutation::synth_batch(
            dataset.num_vertices(),
            &[],
            batch_size,
            0.0,
            false,
            0xF16_12 + r as u64,
        );
        applied += batch.len() as u64;
        mutation::ingest(&dir, &batch, 0.01)?;
    }
    let apply_wall = t_apply.elapsed();
    let rate = applied as f64 / apply_wall.as_secs_f64().max(1e-9);

    // 2) incremental restart vs cold start on the mutated graph
    let engine = VswEngine::open(dir.clone(), EngineConfig::default())?;
    let property = Property::load(&dir.property_path())?;
    let manifest = EpochManifest::load_or_bootstrap(&dir, &property)?;
    let plan = mutation::incremental_plan(&dir, &manifest, 0, engine.epoch())?
        .expect("insert-only history must be incremental-eligible");
    assert!(!plan.has_resets(), "insert-only history must not require resets");
    let seed = plan.seed;
    let seed_len = seed.len();
    let t_warm = Instant::now();
    let warm =
        engine.run_seeded(&app, Some(WarmStart { values: base.values.clone(), active: seed }))?;
    let warm_wall = t_warm.elapsed();
    let t_cold = Instant::now();
    let cold = engine.run(&app)?;
    let cold_wall = t_cold.elapsed();
    assert_eq!(warm.values, cold.values, "incremental restart diverged from cold start");

    // 3) compaction: merged rewrite, then bit-identical re-execution
    let t_compact = Instant::now();
    let creport = mutation::compact(&dir, 0.0)?;
    let compact_wall = t_compact.elapsed();
    let engine = VswEngine::open(dir.clone(), EngineConfig::default())?;
    let after = engine.run(&app)?;
    assert_eq!(after.values, cold.values, "compaction changed results");

    let mut table = Table::new(
        &format!("Fig12 dynamic graph ({})", dataset.name),
        &["leg", "total", "detail"],
    );
    table.row(&[
        "ingest".into(),
        humansize::duration(apply_wall),
        format!("{applied} mutations, {rate:.0}/s, {} epochs", rounds),
    ]);
    table.row(&[
        "incremental".into(),
        humansize::duration(warm_wall),
        format!(
            "{} iters from warm seed ({seed_len} vertices) vs {} cold in {}",
            warm.stats.num_iters(),
            cold.stats.num_iters(),
            humansize::duration(cold_wall)
        ),
    ]);
    table.row(&[
        "compact".into(),
        humansize::duration(compact_wall),
        format!("{} shards merged", creport.compacted_shards.len()),
    ]);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    benchjson::record_if_requested(&BenchRecord::from_stats(
        "fig_ingest_apply",
        apply_wall,
        &RunStats::default(),
    ))?;
    benchjson::record_if_requested(&BenchRecord::from_stats(
        "fig_ingest_incremental",
        warm_wall,
        &warm.stats,
    ))?;
    benchjson::record_if_requested(&BenchRecord::from_stats(
        "fig_ingest_compact",
        compact_wall,
        &RunStats::default(),
    ))?;
    let _ = std::fs::remove_dir_all(&dir.root);
    Ok(())
}
