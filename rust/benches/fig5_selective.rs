//! Fig 5 — effect of the selective scheduling mechanism.
//!
//! Paper setup: PageRank / SSSP / WCC on UK-2007 for 200 iterations,
//! GraphMP-SS (selective scheduling on) vs GraphMP-NSS (off), reporting the
//! vertex-activation ratio and the per-iteration execution time.  This
//! driver adds the adaptive-I/O-governor ablation: every app also runs with
//! `--adaptive` (same selective setting), so the table shows what the
//! feedback loop changes relative to the fixed prefetch window.
//!
//! Expected shape: per-iteration time of -SS drops below -NSS once the
//! activation ratio falls under the 0.001 threshold; SSSP benefits most
//! (paper: up to 2.86× per iteration, 50.1% overall), WCC moderately
//! (1.75×, 9.5%), PageRank least and latest (1.67×, 5.8%).  The adaptive
//! rows must produce identical iteration counts/skips (determinism) while
//! the window column shows where the governor settled.
//!
//! `--quick` (the CI bench-smoke mode): tiny dataset, 20 iterations, and a
//! machine-readable record appended to `$GRAPHMP_BENCH_JSON` if set.

use std::time::Instant;

use graphmp::apps::{self, VertexProgram};
use graphmp::cache::Codec;
use graphmp::coordinator::benchjson::{self, BenchRecord};
use graphmp::coordinator::cli::Args;
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::experiment::{
    ensure_dataset, run_graphmp, run_graphmp_adaptive, GraphMpVariant,
};
use graphmp::coordinator::report;
use graphmp::engine::RunStats;
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let t_bench = Instant::now();
    let args = Args::parse(std::env::args().skip(1), &["quick", "bench"])?;
    let quick = args.has("quick");
    let dataset = if quick {
        Dataset::by_name("tiny")?
    } else {
        Dataset::by_name(
            &std::env::var("GRAPHMP_FIG5_DATASET").unwrap_or_else(|_| "uk2007-s".into()),
        )?
    };
    let iters: usize = if quick {
        20
    } else {
        std::env::var("GRAPHMP_FIG5_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(200)
    };
    println!("Fig 5: selective scheduling on {} ({iters} iterations)", dataset.name);
    let dir = ensure_dataset(dataset)?;

    let apps_list: Vec<Box<dyn VertexProgram>> = vec![
        apps::by_name("pagerank")?.into_f32()?,
        apps::by_name("sssp")?.into_f32()?,
        apps::by_name("wcc")?.into_f32()?,
    ];
    let mut table = Table::new(
        &format!("Fig5 {} ({iters} iters)", dataset.name),
        &[
            "app",
            "variant",
            "prefetch",
            "iters",
            "total",
            "skipped-shards",
            "first-selective-iter",
            "max-iter-speedup",
            "overall-gain",
        ],
    );
    // the CI gate records the first adaptive run's engine statistics
    let mut gate_stats: Option<RunStats> = None;

    for app in &apps_list {
        let variant = GraphMpVariant::Cached(Codec::SnapLite);
        let (ss, _) = run_graphmp(&dir, variant, true, app.as_ref(), iters)?;
        let (ssa, _) = run_graphmp_adaptive(&dir, variant, true, app.as_ref(), iters)?;
        let (nss, _) = run_graphmp(&dir, variant, false, app.as_ref(), iters)?;
        if gate_stats.is_none() {
            gate_stats = Some(ssa.stats.clone());
        }

        // per-iteration speedup where both ran (paper Fig 5 a2/b2/c2)
        let mut max_speedup = 0.0f64;
        for (a, b) in ss.stats.iters.iter().zip(&nss.stats.iters) {
            if a.selective_enabled {
                let s = b.wall.as_secs_f64() / a.wall.as_secs_f64().max(1e-12);
                max_speedup = max_speedup.max(s);
            }
        }
        let first_sel = ss
            .stats
            .iters
            .iter()
            .find(|i| i.selective_enabled)
            .map(|i| i.iter.to_string())
            .unwrap_or_else(|| "-".into());
        let gain = |run: &RunStats| {
            100.0 * (1.0 - run.total_wall.as_secs_f64() / nss.stats.total_wall.as_secs_f64())
        };
        let skipped =
            |run: &RunStats| -> usize { run.iters.iter().map(|i| i.shards_skipped).sum() };
        table.row(&[
            app.name().into(),
            "GraphMP-SS".into(),
            "fixed(2)".into(),
            ss.stats.num_iters().to_string(),
            humansize::duration(ss.stats.total_wall),
            skipped(&ss.stats).to_string(),
            first_sel.clone(),
            format!("{max_speedup:.2}x"),
            format!("{:.1}%", gain(&ss.stats)),
        ]);
        table.row(&[
            app.name().into(),
            "GraphMP-SS-A".into(),
            format!("adaptive→{}", ssa.stats.final_prefetch_depth()),
            ssa.stats.num_iters().to_string(),
            humansize::duration(ssa.stats.total_wall),
            skipped(&ssa.stats).to_string(),
            first_sel,
            "-".into(),
            format!("{:.1}%", gain(&ssa.stats)),
        ]);
        table.row(&[
            app.name().into(),
            "GraphMP-NSS".into(),
            "fixed(2)".into(),
            nss.stats.num_iters().to_string(),
            humansize::duration(nss.stats.total_wall),
            "0".into(),
            "-".into(),
            "1.00x".into(),
            "-".into(),
        ]);

        // activation-ratio curve samples (paper Fig 5 a1/b1/c1)
        print!("  {} activation ratio:", app.name());
        let samples = [0usize, 1, 2, 5, 10, 20, 50, 100, 150, iters.saturating_sub(1)];
        for &s in samples.iter().filter(|&&s| s < ss.stats.iters.len()) {
            print!(" i{}={:.4}", s, ss.stats.iters[s].active_ratio);
        }
        println!();
    }
    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    if let Some(stats) = &gate_stats {
        benchjson::record_if_requested(&BenchRecord::from_stats(
            "fig5_selective",
            t_bench.elapsed(),
            stats,
        ))?;
    }
    Ok(())
}
