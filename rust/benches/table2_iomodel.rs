//! Table II — the analytic per-iteration I/O model vs what the engines
//! actually do: run one steady-state PageRank iteration per system, read
//! the global byte counters, and compare with `iomodel`'s closed forms.
//!
//! Expected shape: measured read/write within tens of percent of each
//! model's prediction (C=4, D varies per layout: 8 B raw pairs for
//! ESG/DSW; ~4 B CSR col + row_ptr amortized for PSW/VSP/VSW), and the
//! ordering PSW > ESG > {VSP, DSW} > VSW preserved exactly.
//!
//! Known idealization gaps (the paper's formulas, not bugs here):
//! * ESG: a real update record carries the destination id, so it is
//!   4+C = 8 B while Table II counts C = 4 B — measured write ≈ 2×
//!   prediction, read correspondingly higher.
//! * DSW: Table II charges C·√P·V writes, but GridGraph's own §3 text
//!   writes each destination chunk once per column pass ⇒ C·V per
//!   iteration; this implementation follows the text, so measured write ≈
//!   prediction/√P.

use graphmp::apps::PageRank;
use graphmp::baselines::{self, OocEngine};
use graphmp::coordinator::datasets::Dataset;
use graphmp::coordinator::experiment::{ensure_dataset, GraphMpVariant};
use graphmp::coordinator::report;
use graphmp::engine::VswEngine;
use graphmp::iomodel::{Model, ModelParams};
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let dataset = Dataset::by_name("twitter-s")?;
    println!("Table II: analytic model vs measured I/O ({}, PageRank)", dataset.name);
    let dir = ensure_dataset(dataset)?;
    let edges = dataset.generate();
    let (v, e) = (dataset.num_vertices() as u64, edges.len() as u64);

    let mut table = Table::new(
        "TableII predicted vs measured bytes/iteration (twitter-s, PageRank)",
        &["model", "pred read", "meas read", "err", "pred write", "meas write", "err"],
    );

    let mut add_row = |name: &str, model: Model, p: ModelParams, read: u64, write: u64| {
        let pred = model.predict(&p);
        let fmt_err = |m: u64, pr: f64| {
            if pr == 0.0 && m == 0 {
                "0%".to_string()
            } else if pr == 0.0 {
                "inf".to_string()
            } else {
                format!("{:.0}%", 100.0 * (m as f64 - pr).abs() / pr)
            }
        };
        table.row(&[
            name.into(),
            humansize::bytes(pred.read as u64),
            humansize::bytes(read),
            fmt_err(read, pred.read),
            humansize::bytes(pred.write as u64),
            humansize::bytes(write),
            fmt_err(write, pred.write),
        ]);
    };

    // ---- PSW (D ≈ 12: 4B CSR col entry + 8B paired-direction record) -----
    {
        let mut eng = baselines::PswEngine::new(std::env::temp_dir().join("gmp_t2_psw"));
        eng.prepare(&edges, v as usize)?;
        let run = eng.run(&PageRank::default(), 3)?;
        let io = run.iter_io[1]; // steady state
        // PSW stores value+structure per edge in both directions: C+D with
        // D≈8 (edge record) — the paper's (C+D)=12 B/edge
        let p = ModelParams {
            v,
            e,
            p: run.iter_walls.len().max(8) as u64,
            c: 4,
            d: 8,
            n_cores: 1,
            theta: 1.0,
        };
        add_row("PSW (GraphChi)", Model::Psw, p, io.bytes_read, io.bytes_written);
    }

    // ---- ESG (D = 8 raw pairs) -------------------------------------------
    {
        let mut eng = baselines::EsgEngine::new(std::env::temp_dir().join("gmp_t2_esg"));
        eng.prepare(&edges, v as usize)?;
        let run = eng.run(&PageRank::default(), 3)?;
        let io = run.iter_io[1];
        let p = ModelParams { v, e, p: 8, c: 4, d: 8, n_cores: 1, theta: 1.0 };
        add_row("ESG (X-Stream)", Model::Esg, p, io.bytes_read, io.bytes_written);
    }

    // ---- DSW (√P = 4 grid) ------------------------------------------------
    {
        let mut eng = baselines::DswEngine::new(std::env::temp_dir().join("gmp_t2_dsw"));
        eng.prepare(&edges, v as usize)?;
        let run = eng.run_full(&PageRank::default(), 3)?;
        let io = run.iter_io[1];
        let p = ModelParams { v, e, p: 16, c: 4, d: 8, n_cores: 1, theta: 1.0 };
        add_row("DSW (GridGraph)", Model::Dsw, p, io.bytes_read, io.bytes_written);
    }

    // ---- VSP (D ≈ 5: CSR col + amortized row_ptr) --------------------------
    {
        let mut eng = baselines::VspEngine::new(std::env::temp_dir().join("gmp_t2_vsp"));
        eng.prepare(&edges, v as usize)?;
        let shards = eng.delta(); // force prepare-derived P before run
        let _ = shards;
        let run = eng.run(&PageRank::default(), 3)?;
        let io = run.iter_io[1];
        let p = ModelParams { v, e, p: 84, c: 4, d: 5, n_cores: 1, theta: 1.0 };
        add_row("VSP (VENUS)", Model::Vsp, p, io.bytes_read, io.bytes_written);
    }

    // ---- VSW: cache off => θ=1; cache on => θ=0 ----------------------------
    {
        let engine = VswEngine::open(dir.clone(), GraphMpVariant::NoCache.to_config(false, 3))?;
        let run = engine.run(&PageRank::default())?;
        let io = run.stats.iters[1].io;
        let shards = engine.property().num_shards() as u64;
        let p = ModelParams { v, e, p: shards, c: 4, d: 5, n_cores: 1, theta: 1.0 };
        add_row("VSW θ=1 (GraphMP-NC)", Model::Vsw, p, io.bytes_read, io.bytes_written);

        let engine = VswEngine::open(
            dir,
            GraphMpVariant::Cached(graphmp::cache::Codec::SnapLite).to_config(false, 3),
        )?;
        let run = engine.run(&PageRank::default())?;
        let io = run.stats.iters[1].io;
        let p = ModelParams { v, e, p: shards, c: 4, d: 5, n_cores: 1, theta: 0.0 };
        add_row("VSW θ=0 (GraphMP-C)", Model::Vsw, p, io.bytes_read, io.bytes_written);
    }

    table.print();
    report::append_markdown(&report::results_path(), &table)?;
    Ok(())
}
