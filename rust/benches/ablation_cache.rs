//! Ablation — compressed-cache modes (§II-D.2): compression ratio,
//! compress/decompress cost and end-to-end engine impact for the paper's
//! four modes plus the two extension codecs, and a constrained-budget sweep
//! showing why higher ratios win when memory is tight.
//!
//! Expected shape: ratio none < snaplite < zlib-1 ≤ zlib-3 (with
//! delta-varint beating zlib on CSR payloads); decompress cost in the same
//! order; with an unconstrained budget mode-1 is fastest (no decompression),
//! with a tight budget the compressed modes win by keeping θ low.

use std::time::Instant;

use graphmp::apps::PageRank;
use graphmp::cache::Codec;
use graphmp::coordinator::experiment::{ablation_dataset, ensure_dataset};
use graphmp::coordinator::report;
use graphmp::engine::{EngineConfig, VswEngine};
use graphmp::storage::{io, shardfile};
use graphmp::util::bench::Table;
use graphmp::util::humansize;

fn main() -> anyhow::Result<()> {
    let dataset = ablation_dataset();
    println!("Ablation: cache modes on {}", dataset.name);
    let dir = ensure_dataset(dataset)?;

    // ---- codec-level: ratio + speed on the real shard payloads ----------
    let prop = graphmp::storage::property::Property::load(&dir.property_path())?;
    let payloads: Vec<Vec<u8>> = (0..prop.num_shards())
        .map(|i| io::read_file(&dir.shard_path(i)))
        .collect::<anyhow::Result<_>>()?;
    let raw_total: usize = payloads.iter().map(|p| p.len()).sum();

    let mut table = Table::new(
        &format!("cache codecs on {} ({} shards, {})", dataset.name, payloads.len(),
                 humansize::bytes(raw_total as u64)),
        &["mode", "codec", "ratio", "compress", "decompress", "engine 10-iter"],
    );
    for codec in Codec::ALL {
        let t0 = Instant::now();
        let compressed: Vec<Vec<u8>> = payloads
            .iter()
            .map(|p| codec.compress(p))
            .collect::<anyhow::Result<_>>()?;
        let c_time = t0.elapsed();
        let c_total: usize = compressed.iter().map(|c| c.len()).sum();
        let t0 = Instant::now();
        for c in &compressed {
            let shard = codec.decompress_shard(c)?;
            std::hint::black_box(shard.num_edges());
        }
        let d_time = t0.elapsed();

        // end-to-end engine run with this codec
        let engine = VswEngine::open(
            dir.clone(),
            EngineConfig { max_iters: 10, cache_codec: codec, ..Default::default() },
        )?;
        let run = engine.run(&PageRank::default())?;

        table.row(&[
            format!("mode-{}", codec.mode_number()),
            codec.name().into(),
            format!("{:.2}x", raw_total as f64 / c_total as f64),
            humansize::duration(c_time),
            humansize::duration(d_time),
            humansize::duration(run.stats.total_wall),
        ]);
    }
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    // ---- budget sweep: tight memory makes compression pay ----------------
    // the paper's regime is disk-bound: throttle to HDD bandwidth so the
    // (cache miss => disk read) cost dominates the decompression cost
    io::set_throttle(graphmp::coordinator::experiment::figure_throttle_mbps() << 20);
    let mut table = Table::new(
        "constrained cache budget (PageRank 10 iters, budget = 30% of raw)",
        &["codec", "hit-ratio", "disk read", "total"],
    );
    let budget = raw_total * 3 / 10;
    for codec in [Codec::None, Codec::SnapLite, Codec::Zlib3, Codec::DeltaVarint] {
        let engine = VswEngine::open(
            dir.clone(),
            EngineConfig {
                max_iters: 10,
                cache_codec: codec,
                cache_budget: budget,
                ..Default::default()
            },
        )?;
        let run = engine.run(&PageRank::default())?;
        let read: u64 = run.stats.iters.iter().map(|i| i.io.bytes_read).sum();
        table.row(&[
            codec.name().into(),
            format!("{:.2}", engine.cache().stats.hit_ratio()),
            humansize::bytes(read),
            humansize::duration(run.stats.total_wall),
        ]);
    }
    io::set_throttle(0);
    table.print();
    report::append_markdown(&report::results_path(), &table)?;

    // sanity: the shard files really are what the codecs think they are
    let first = shardfile::from_bytes(&payloads[0])?;
    assert!(first.num_edges() > 0);
    Ok(())
}
