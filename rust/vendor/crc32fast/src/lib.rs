//! Offline shim for the `crc32fast` crate: a table-driven CRC-32 (IEEE
//! 802.3, the zlib/PNG polynomial) exposing the same `Hasher` API.  No SIMD,
//! but byte-for-byte compatible checksums, which is all the framed on-disk
//! formats need.

const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut k = 0;
        while k < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            k += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Incremental CRC-32 state.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    pub fn new() -> Self {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot convenience.
pub fn hash(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard CRC-32 check values
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_equals_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), hash(&data));
    }

    #[test]
    fn detects_single_bitflips() {
        let data = b"some payload worth protecting".to_vec();
        let want = hash(&data);
        for byte in 0..data.len() {
            let mut bad = data.clone();
            bad[byte] ^= 0x20;
            assert_ne!(hash(&bad), want, "undetected flip at {byte}");
        }
    }
}
