//! Offline shim for the `xla` PJRT binding.
//!
//! The container ships no PJRT plugin or XLA shared library, so this crate
//! provides the exact API surface `graphmp::runtime` compiles against while
//! reporting PJRT as unavailable at client-construction time
//! ([`PjRtClient::cpu`] returns `Err`).  Every caller of the runtime
//! (engine backends, tests, examples, the CLI's `--engine xla`) already
//! treats "runtime failed to load" as "fall back to native / skip", so the
//! three-layer path degrades gracefully instead of breaking the build.
//!
//! When a real PJRT environment exists, this directory is the single swap
//! point: replace the shim with the real binding, nothing else changes.

use std::fmt;
use std::path::PathBuf;

/// Error type surfaced by every fallible call (`{:?}`-formatted upstream).
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

type Result<T> = std::result::Result<T, XlaError>;

/// A parsed HLO module (text form retained; nothing interprets it here).
pub struct HloModuleProto {
    pub path: PathBuf,
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading {path}: {e}")))?;
        Ok(Self { path: PathBuf::from(path), text })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { _text: proto.text.clone() }
    }
}

/// PJRT client handle.  Construction fails in this shim.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(XlaError(
            "PJRT plugin not available in this build (vendored xla shim); \
             use the native backend"
                .into(),
        ))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError("PJRT unavailable (vendored xla shim)".into()))
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: AsLiteral>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError("PJRT unavailable (vendored xla shim)".into()))
    }
}

/// A device buffer returned by `execute`.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {
    fn wrap(vals: &[Self]) -> Literal;
    fn unwrap(lit: &Literal) -> Option<Vec<Self>>;
}

/// A host literal (rank-1 only — all the runtime ever builds).
#[derive(Clone)]
pub enum Literal {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NativeType for f32 {
    fn wrap(vals: &[Self]) -> Literal {
        Literal::F32(vals.to_vec())
    }

    fn unwrap(lit: &Literal) -> Option<Vec<Self>> {
        match lit {
            Literal::F32(v) => Some(v.clone()),
            Literal::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(vals: &[Self]) -> Literal {
        Literal::I32(vals.to_vec())
    }

    fn unwrap(lit: &Literal) -> Option<Vec<Self>> {
        match lit {
            Literal::I32(v) => Some(v.clone()),
            Literal::F32(_) => None,
        }
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(vals: &[T]) -> Literal {
        T::wrap(vals)
    }

    /// Unwrap a 1-tuple result (identity here: rank-1 literals only).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self).ok_or_else(|| XlaError("literal element type mismatch".into()))
    }
}

/// Marker for types accepted by [`PjRtLoadedExecutable::execute`].
pub trait AsLiteral {}

impl AsLiteral for Literal {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("shim must fail");
        assert!(format!("{err:?}").contains("PJRT"));
    }

    #[test]
    fn literals_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert!(l.to_vec::<i32>().is_err());
        let l = Literal::vec1(&[3i32]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![3]);
    }
}
