//! Offline shim for the `anyhow` crate.
//!
//! The build container has no crates.io access, so this workspace vendors
//! the small slice of anyhow's API that the `graphmp` crate actually uses:
//!
//! * [`Error`] — a context-chain error value (no backtraces, no downcast);
//! * [`Result`] — `Result<T, Error>` alias;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Formatting matches real anyhow where the repo depends on it: `{}` prints
//! the outermost message, `{:#}` prints the whole chain joined by `": "`,
//! and `{:?}` prints the message plus a `Caused by:` list.

use std::fmt;

/// A context-chain error: `chain[0]` is the outermost (most recent)
/// message, `chain.last()` the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes this blanket conversion coherent (same trick as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_outer_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading shard 3").context("open dataset");
        assert_eq!(format!("{e}"), "open dataset");
        assert_eq!(format!("{e:#}"), "open dataset: reading shard 3: disk on fire");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 42)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 42");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(5).is_err());
        assert!(format!("{:#}", f(12).unwrap_err()).contains("x too big: 12"));
        let e = anyhow!("plain {}", "message");
        assert_eq!(format!("{e}"), "plain message");
    }

    #[test]
    fn context_on_anyhow_result_keeps_chain() {
        let base: Result<()> = Err(anyhow!("root"));
        let e = base.context("mid").context("top").unwrap_err();
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert_eq!(e.root_cause(), "root");
    }
}
