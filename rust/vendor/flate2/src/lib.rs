//! Offline shim for the `flate2` crate.
//!
//! The build container has no crates.io access and no zlib binding, so this
//! crate reproduces the *API shape* the workspace uses
//! (`write::ZlibEncoder`, `read::ZlibDecoder`, `Compression`) on top of a
//! from-scratch LZSS byte codec ([`lzss`]).  The stream format is this
//! shim's own — round-trips within the process (all the shard cache needs)
//! but is **not** RFC 1950 zlib interop.
//!
//! Compression levels map to match-search effort: higher levels walk longer
//! hash chains and find longer matches, mirroring zlib's level/ratio trade.

use std::io::{self, Read, Write};

/// Compression level knob (zlib-style 0-9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Self {
        Compression(level.min(9))
    }

    pub fn level(&self) -> u32 {
        self.0
    }
}

impl Default for Compression {
    fn default() -> Self {
        Compression(6)
    }
}

/// The LZSS engine shared with the vendored `zstd` shim.
pub mod lzss {
    const MIN_MATCH: usize = 4;
    const HASH_BITS: u32 = 16;
    const WINDOW: usize = 1 << 20;

    #[inline]
    fn hash4(b: &[u8]) -> usize {
        let x = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        (x.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
    }

    fn write_varint(out: &mut Vec<u8>, mut v: u64) {
        loop {
            let byte = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                out.push(byte);
                return;
            }
            out.push(byte | 0x80);
        }
    }

    fn read_varint(buf: &[u8], mut pos: usize) -> Option<(u64, usize)> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let &byte = buf.get(pos)?;
            pos += 1;
            if shift >= 63 && byte > 1 {
                return None;
            }
            v |= ((byte & 0x7F) as u64) << shift;
            if byte & 0x80 == 0 {
                return Some((v, pos));
            }
            shift += 7;
            if shift > 63 {
                return None;
            }
        }
    }

    fn emit_literals(out: &mut Vec<u8>, lit: &[u8]) {
        if lit.is_empty() {
            return;
        }
        let n = lit.len();
        if n < 127 {
            out.push((n as u8) << 1);
        } else {
            out.push(127 << 1);
            write_varint(out, (n - 127) as u64);
        }
        out.extend_from_slice(lit);
    }

    fn emit_copy(out: &mut Vec<u8>, len: usize, dist: usize) {
        let lcode = len - MIN_MATCH;
        if lcode < 127 {
            out.push(((lcode as u8) << 1) | 1);
        } else {
            out.push((127 << 1) | 1);
            write_varint(out, (lcode - 127) as u64);
        }
        write_varint(out, dist as u64);
    }

    /// Greedy LZSS with hash-chain longest-match search (up to `chain`
    /// candidates per position).  `chain >= 1`.
    pub fn compress(input: &[u8], chain: usize) -> Vec<u8> {
        let chain = chain.max(1);
        let mut out = Vec::with_capacity(input.len() / 2 + 16);
        out.extend_from_slice(&(input.len() as u64).to_le_bytes());
        if input.is_empty() {
            return out;
        }

        // head[h] = most recent position with hash h; prev[p] = previous
        // position with p's hash (chained back in time)
        let mut head = vec![usize::MAX; 1 << HASH_BITS];
        let mut prev = vec![usize::MAX; input.len()];

        let insert = |head: &mut [usize], prev: &mut [usize], p: usize, input: &[u8]| {
            let h = hash4(&input[p..]);
            prev[p] = head[h];
            head[h] = p;
        };

        let mut pos = 0usize;
        let mut lit_start = 0usize;
        while pos + MIN_MATCH <= input.len() {
            // longest match across the chain
            let mut best_len = 0usize;
            let mut best_dist = 0usize;
            let mut cand = head[hash4(&input[pos..])];
            let max = input.len() - pos;
            let mut steps = 0usize;
            while cand != usize::MAX && steps < chain {
                let dist = pos - cand;
                if dist > WINDOW {
                    break;
                }
                if input[cand..cand + MIN_MATCH] == input[pos..pos + MIN_MATCH] {
                    let mut len = MIN_MATCH;
                    while len < max && input[cand + len] == input[pos + len] {
                        len += 1;
                    }
                    if len > best_len {
                        best_len = len;
                        best_dist = dist;
                        if len == max {
                            break;
                        }
                    }
                }
                cand = prev[cand];
                steps += 1;
            }

            if best_len >= MIN_MATCH {
                emit_literals(&mut out, &input[lit_start..pos]);
                emit_copy(&mut out, best_len, best_dist);
                // index positions inside the match (sparsely for speed)
                let end = pos + best_len;
                insert(&mut head, &mut prev, pos, input);
                let mut p = pos + 1;
                while p + MIN_MATCH <= input.len() && p < end {
                    insert(&mut head, &mut prev, p, input);
                    p += 2;
                }
                pos = end;
                lit_start = pos;
            } else {
                insert(&mut head, &mut prev, pos, input);
                pos += 1;
            }
        }
        emit_literals(&mut out, &input[lit_start..]);
        out
    }

    /// Invert [`compress`]; validates structure and the length header.
    pub fn decompress(input: &[u8]) -> Result<Vec<u8>, String> {
        if input.len() < 8 {
            return Err("lzss: header truncated".into());
        }
        let expect = u64::from_le_bytes(input[0..8].try_into().unwrap()) as usize;
        let mut out = Vec::with_capacity(expect);
        let mut pos = 8usize;
        while pos < input.len() {
            let tag = input[pos];
            pos += 1;
            let mut field = (tag >> 1) as usize;
            if field == 127 {
                let Some((ext, p)) = read_varint(input, pos) else {
                    return Err("lzss: bad length extension".into());
                };
                field += ext as usize;
                pos = p;
            }
            if tag & 1 == 0 {
                if pos + field > input.len() {
                    return Err("lzss: literal overruns input".into());
                }
                out.extend_from_slice(&input[pos..pos + field]);
                pos += field;
            } else {
                let len = field + MIN_MATCH;
                let Some((dist, p)) = read_varint(input, pos) else {
                    return Err("lzss: bad distance".into());
                };
                pos = p;
                let dist = dist as usize;
                if dist < 1 || dist > out.len() {
                    return Err(format!("lzss: distance {dist} out of range"));
                }
                let start = out.len() - dist;
                let mut copied = 0usize;
                while copied < len {
                    let src = start + copied;
                    let n = (out.len() - src).min(len - copied);
                    out.extend_from_within(src..src + n);
                    copied += n;
                }
            }
        }
        if out.len() != expect {
            return Err(format!("lzss: length mismatch {} vs {}", out.len(), expect));
        }
        Ok(out)
    }

    /// Match-search chain depth for a zlib-style level.
    pub fn chain_for_level(level: u32) -> usize {
        match level {
            0 | 1 => 8,
            2 => 16,
            3 | 4 => 32,
            5 | 6 => 64,
            _ => 128,
        }
    }
}

pub mod write {
    use super::{lzss, Compression};
    use std::io::{self, Write};

    /// Buffering encoder: collects all input, compresses on `finish()`.
    pub struct ZlibEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
        level: Compression,
    }

    impl<W: Write> ZlibEncoder<W> {
        pub fn new(inner: W, level: Compression) -> Self {
            Self { inner, buf: Vec::new(), level }
        }

        /// Compress everything written so far, flush it to the inner
        /// writer, and return the writer.
        pub fn finish(mut self) -> io::Result<W> {
            let compressed = lzss::compress(&self.buf, lzss::chain_for_level(self.level.level()));
            self.inner.write_all(&compressed)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for ZlibEncoder<W> {
        fn write(&mut self, data: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(data);
            Ok(data.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use super::lzss;
    use std::io::{self, Read};

    /// Eager decoder: drains the inner reader and decompresses on first
    /// read, then serves from an in-memory cursor.
    pub struct ZlibDecoder<R: Read> {
        inner: R,
        decoded: Option<Vec<u8>>,
        pos: usize,
    }

    impl<R: Read> ZlibDecoder<R> {
        pub fn new(inner: R) -> Self {
            Self { inner, decoded: None, pos: 0 }
        }

        fn ensure_decoded(&mut self) -> io::Result<()> {
            if self.decoded.is_none() {
                let mut raw = Vec::new();
                self.inner.read_to_end(&mut raw)?;
                let out = lzss::decompress(&raw)
                    .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
                self.decoded = Some(out);
            }
            Ok(())
        }
    }

    impl<R: Read> Read for ZlibDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.ensure_decoded()?;
            let data = self.decoded.as_ref().unwrap();
            let n = buf.len().min(data.len() - self.pos);
            buf[..n].copy_from_slice(&data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8], level: u32) -> Vec<u8> {
        let mut enc = write::ZlibEncoder::new(Vec::new(), Compression::new(level));
        enc.write_all(data).unwrap();
        let compressed = enc.finish().unwrap();
        let mut dec = read::ZlibDecoder::new(compressed.as_slice());
        let mut out = Vec::new();
        dec.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
        compressed
    }

    #[test]
    fn roundtrip_edges() {
        for level in [1, 3, 9] {
            roundtrip(b"", level);
            roundtrip(b"a", level);
            roundtrip(b"abcdabcdabcdabcd", level);
            roundtrip(&vec![0x5Au8; 100_000], level);
        }
    }

    #[test]
    fn compresses_structured_data() {
        // quantized monotone u32s: runs of identical 4-byte groups, the
        // repetitive-structure shape CSR arrays exhibit
        let ids: Vec<u32> = (0..40_000u32).map(|i| i / 3).collect();
        let bytes: Vec<u8> = ids.iter().flat_map(|x| x.to_le_bytes()).collect();
        let c = roundtrip(&bytes, 1);
        assert!(c.len() < bytes.len(), "level 1 did not compress: {} vs {}", c.len(), bytes.len());
        // deeper chains find longer matches; greedy parsing means "no worse"
        // only holds statistically, so allow 1% slack
        let c3 = roundtrip(&bytes, 3);
        assert!(
            c3.len() <= c.len() + c.len() / 100,
            "level 3 ({}) much worse than level 1 ({})",
            c3.len(),
            c.len()
        );
    }

    #[test]
    fn corrupt_stream_is_an_error() {
        let mut enc = write::ZlibEncoder::new(Vec::new(), Compression::new(3));
        enc.write_all(b"hello hello hello hello hello").unwrap();
        let mut c = enc.finish().unwrap();
        c.truncate(c.len() - 1);
        let mut dec = read::ZlibDecoder::new(c.as_slice());
        let mut out = Vec::new();
        assert!(dec.read_to_end(&mut out).is_err());
    }

    #[test]
    fn random_data_roundtrips() {
        // xorshift-ish deterministic noise
        let mut x = 0x1234_5678_9ABC_DEF0u64;
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                x as u8
            })
            .collect();
        roundtrip(&data, 1);
        roundtrip(&data, 9);
    }
}
