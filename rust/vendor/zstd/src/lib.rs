//! Offline shim for the `zstd` crate's bulk API.
//!
//! No crates.io access and no libzstd in the container, so `bulk::compress`
//! / `bulk::decompress` are backed by the vendored LZSS engine (see the
//! `flate2` shim) at a deep match-search setting — "fast codec, decent
//! ratio", the same design point the real zstd-1 occupies in the cache's
//! mode ablation.  The byte format is this workspace's own, not the zstd
//! frame format.

pub mod bulk {
    use std::io;

    /// Deep-chain LZSS — deeper search than any zlib level the shim maps,
    /// so "zstd-1" keeps its place as the best-ratio byte codec.
    const CHAIN: usize = 192;

    pub fn compress(source: &[u8], _level: i32) -> io::Result<Vec<u8>> {
        Ok(flate2::lzss::compress(source, CHAIN))
    }

    /// `capacity` bounds the decoded size (the caller's memory budget).
    pub fn decompress(source: &[u8], capacity: usize) -> io::Result<Vec<u8>> {
        let out = flate2::lzss::decompress(source)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
        if out.len() > capacity {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("decoded size {} exceeds capacity {}", out.len(), capacity),
            ));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::bulk;

    #[test]
    fn roundtrip_and_capacity() {
        let data: Vec<u8> = (0..30_000u32).flat_map(|i| (i / 3).to_le_bytes()).collect();
        let c = bulk::compress(&data, 1).unwrap();
        assert!(c.len() < data.len(), "did not compress");
        assert_eq!(bulk::decompress(&c, 1 << 30).unwrap(), data);
        assert!(bulk::decompress(&c, 10).is_err(), "capacity not enforced");
    }

    #[test]
    fn empty_roundtrip() {
        let c = bulk::compress(b"", 1).unwrap();
        assert_eq!(bulk::decompress(&c, 1 << 20).unwrap(), b"");
    }
}
